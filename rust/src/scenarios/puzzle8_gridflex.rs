//! Puzzle 8 (§4.8, Table 9): how much grid power can I shed without an SLO
//! breach?
//!
//! `grid_flex_analysis()` sweeps demand-response depths for a 40x H100
//! fleet on Azure at λ=200: logistic power inversion -> batch cap ->
//! recalibrated M/G/c -> DES verification (steady state + 75 s event
//! window). Each flex level is an independent (analysis + 2x DES) unit and
//! fans out over the engine's worker threads. (The DES runs inside
//! `grid_flex_analysis` manage their own request sampling — cap windows
//! need the raw arrival times — so this scenario gains parallelism, not
//! the engine's stream cache.)

use crate::optimizer::engine::EvalEngine;
use crate::optimizer::gridflex::{grid_flex_analysis, FlexPoint,
                                 GridFlexConfig};
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{millis, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 200.0;
pub const N_GPUS: usize = 40;
pub const SLO_MS: f64 = 500.0;

pub fn config(opts: &ScenarioOpts) -> GridFlexConfig {
    GridFlexConfig {
        n_gpus: N_GPUS,
        slo_ms: SLO_MS,
        n_requests: opts.n_requests.max(8_000),
        seed: opts.seed,
        ..Default::default()
    }
}

/// Registry entry for the grid demand-response scenario.
pub struct GridFlexibility;

impl Scenario for GridFlexibility {
    fn id(&self) -> &'static str {
        "puzzle8"
    }

    fn name(&self) -> &'static str {
        "gridflex"
    }

    fn title(&self) -> &'static str {
        "How much grid power can I shed without an SLO breach?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", LAMBDA)],
            gpus: vec!["H100"],
            thresholds: vec![],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "RandomRouter",
            topology: Topology::SinglePool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let gpu = engine.catalog.get("H100").unwrap().clone();
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, LAMBDA);
        let cfg = config(opts);
        // One flex level per job: each is an independent power-inversion +
        // M/G/c recalibration + two DES runs.
        let rows: Vec<FlexPoint> = engine
            .par_map(cfg.flex_levels.clone(), |&flex| {
                let level = GridFlexConfig { flex_levels: vec![flex],
                                             ..cfg.clone() };
                grid_flex_analysis(&w, &gpu, &level)
            })
            .into_iter()
            .flatten()
            .collect();

        let mut t = Table::new(&["Flex", "n_max", "W/GPU", "Fleet kW",
                                 "P99 anal.", "P99 DES", "P99 event",
                                 "steady", "event"])
            .with_title(format!(
                "Grid flexibility curve for {N_GPUS} H100 GPUs, λ={LAMBDA} \
                 req/s, SLO={SLO_MS} ms (Azure; logistic power model, \
                 DES-verified, {} requests, {:.0} s event window)",
                cfg.n_requests,
                cfg.event_ms / 1000.0
            ));
        for r in &rows {
            t.row(&[
                format!("{:.0}%", r.flex * 100.0),
                r.n_max.to_string(),
                format!("{:.0} W", r.w_per_gpu),
                format!("{:.1} kW", r.fleet_kw),
                millis(r.p99_analytic_ms),
                millis(r.p99_des_ms),
                millis(r.p99_event_ms),
                check(r.steady_ok).to_string(),
                check(r.event_ok).to_string(),
            ]);
        }

        let steady_depth = rows.iter().take_while(|r| r.steady_ok).count();
        let event_depth = rows.iter().take_while(|r| r.event_ok).count();
        let baseline_kw = rows[0].fleet_kw;
        let saved = rows
            .get(event_depth.saturating_sub(1))
            .map(|r| baseline_kw - r.fleet_kw)
            .unwrap_or(0.0);
        let insight = format!(
            "The safe DR commitment depth depends on event duration: \
             sustained curtailment is stability-limited at {}, while short \
             events tolerate {} (saving {saved:.1} kW of {baseline_kw:.1} kW \
             fleet-wide) before the queue collapses at 50%.",
            rows.get(steady_depth.saturating_sub(1))
                .map(|r| format!("{:.0}%", r.flex * 100.0))
                .unwrap_or_else(|| "0%".into()),
            rows.get(event_depth.saturating_sub(1))
                .map(|r| format!("{:.0}%", r.flex * 100.0))
                .unwrap_or_else(|| "0%".into()),
        );
        PuzzleReport { id: 8, title: self.title().into(), tables: vec![t],
                       insight }
    }
}

/// Legacy entry point (CLI `puzzle 8`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    GridFlexibility.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flex_curve_matches_paper_structure() {
        let report = run(&ScenarioOpts::fast());
        let body = report.tables[0].render();
        // Baseline power and cap columns (Table 9).
        assert!(body.contains("23.3 kW"), "{body}");
        assert!(body.contains("128"), "{body}");
        // 50% flex collapses.
        let last = body.lines().rev().nth(1).unwrap();
        assert!(last.contains("FAIL"), "{body}");
    }
}
