//! Puzzle 5 (§4.5, Table 5): which router causes SLO violations?
//!
//! Same (correctly sized) agent fleet, three routers: the production
//! LengthRouter, the sizing-oriented CompressAndRoute, and the
//! RandomRouter baseline. The sizing router can overload the small short
//! pool it was designed to justify; random spreading dilutes heavy-tail
//! events but is brittle. The three routers simulate in parallel on one
//! cached request stream.

use crate::des::engine::SimPool;
use crate::optimizer::engine::EvalEngine;
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{millis, percent, Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 20.0;
pub const SLO_MS: f64 = 1000.0;
pub const B_SHORT: f64 = 4096.0;
/// Deliberately small short pool (the sizing optimum), as in the paper's
/// (n_s=2, n_l=23) fleet.
pub const N_SHORT: usize = 2;
pub const N_LONG: usize = 40;

#[derive(Debug, Clone)]
pub struct RouterRow {
    pub router: String,
    pub p99_short: f64,
    pub p99_overall: f64,
    pub attainment: f64,
    pub compressed: usize,
}

/// Simulate the three routers in parallel through the given engine.
pub fn evaluate_with(engine: &EvalEngine, opts: &ScenarioOpts)
    -> Vec<RouterRow>
{
    let gpu = engine.catalog.get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, LAMBDA);
    let ctx = w.cdf.max_len();
    let pools = || {
        vec![
            SimPool { gpu: gpu.clone(), n_gpus: N_SHORT, ctx_budget: B_SHORT,
                      batch_cap: None },
            SimPool { gpu: gpu.clone(), n_gpus: N_LONG, ctx_budget: ctx,
                      batch_cap: None },
        ]
    };
    let routers = vec![
        RoutingPolicy::Length { b_short: B_SHORT },
        RoutingPolicy::CompressAndRoute { b_short: B_SHORT, gamma: 2.0 },
        RoutingPolicy::Random { n_pools: 2 },
    ];
    engine.par_map(routers, |router| {
        let mut r = engine.simulate(&w, &pools(), router, &opts.des());
        RouterRow {
            router: router.name().into(),
            p99_short: r.per_pool[0].stats.ttft.p99(),
            p99_overall: r.overall.p99_ttft(),
            attainment: r.attainment(SLO_MS),
            compressed: r.n_compressed,
        }
    })
}

/// Evaluate with a default engine (legacy signature used by benches).
pub fn evaluate(opts: &ScenarioOpts) -> Vec<RouterRow> {
    evaluate_with(&crate::scenarios::default_engine(opts), opts)
}

/// Registry entry for the router-comparison scenario.
pub struct RouterComparison;

impl Scenario for RouterComparison {
    fn id(&self) -> &'static str {
        "puzzle5"
    }

    fn name(&self) -> &'static str {
        "routers"
    }

    fn title(&self) -> &'static str {
        "Which router causes SLO violations?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("agent", LAMBDA)],
            gpus: vec!["H100"],
            thresholds: vec![B_SHORT],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "Length/CompressAndRoute/Random",
            topology: Topology::TwoPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let rows = evaluate_with(engine, opts);
        let mut t = Table::new(&["Router", "P99 short-pool TTFT", "P99 TTFT",
                                 "SLO attainment", "compressed"])
            .with_title(format!(
                "Router comparison on the agent fleet (λ={LAMBDA}, \
                 {N_SHORT}+{N_LONG} H100, SLO={SLO_MS} ms)"
            ))
            .align(&[Align::Left, Align::Right, Align::Right, Align::Right,
                     Align::Right]);
        for r in &rows {
            t.row(&[
                r.router.clone(),
                millis(r.p99_short),
                millis(r.p99_overall),
                percent(r.attainment),
                r.compressed.to_string(),
            ]);
        }
        PuzzleReport {
            id: 5,
            title: self.title().into(),
            tables: vec![t],
            insight: "The router used to size the fleet and the router \
                      deployed in production should differ: CompressAndRoute \
                      funnels borderline agent requests into the 2-GPU short \
                      pool and spikes its P99, while LengthRouter operates \
                      the same fleet safely. RandomRouter dilutes heavy \
                      tails across all slots but couples short requests to \
                      long-request fate — brittle under mix shifts."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `puzzle 5`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    RouterComparison.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compress_hurts_short_pool_vs_length() {
        let rows = evaluate(&ScenarioOpts::fast());
        let length = rows.iter().find(|r| r.router == "LengthRouter").unwrap();
        let compress =
            rows.iter().find(|r| r.router == "CompressAndRoute").unwrap();
        assert!(compress.compressed > 0);
        // Funneling borderline traffic into the tiny short pool must
        // degrade its P99 versus pure length routing. (The paper's fleet
        // shows an outright SLO breach; our slot calibration gives a
        // directional degradation — see EXPERIMENTS.md T5.)
        assert!(
            compress.p99_short > length.p99_short * 1.15,
            "compress {} vs length {}",
            compress.p99_short,
            length.p99_short
        );
        // LengthRouter keeps the short pool fast.
        assert!(length.p99_short < 100.0, "{}", length.p99_short);
    }
}
