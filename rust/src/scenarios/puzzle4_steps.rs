//! Puzzle 4 (§4.4, Table 4): when do I need to add GPUs?
//!
//! What-if λ sweep over an H100 two-pool fleet on Azure: per-bracket
//! minimal fleets, and the exact arrival rate at which each fleet runs out
//! of headroom ("provision more before λ = ..."). Brackets evaluate in
//! parallel.

use crate::optimizer::engine::EvalEngine;
use crate::optimizer::whatif::WhatIfSweep;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDAS: [f64; 7] = [25.0, 50.0, 100.0, 150.0, 200.0, 300.0, 400.0];
pub const SLO_MS: f64 = 500.0;

/// Registry entry for the GPU step-threshold scenario.
pub struct StepThresholds;

impl Scenario for StepThresholds {
    fn id(&self) -> &'static str {
        "puzzle4"
    }

    fn name(&self) -> &'static str {
        "step-thresholds"
    }

    fn title(&self) -> &'static str {
        "When do I need to add GPUs?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", 100.0)],
            gpus: vec!["H100"],
            thresholds: vec![],
            lambda_sweep: LAMBDAS.to_vec(),
            slo_ms: SLO_MS,
            router: "LengthRouter",
            topology: Topology::TwoPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let h100 = engine.catalog.get("H100").unwrap().clone();
        let mut sweep = WhatIfSweep::new(engine.catalog.clone(), SLO_MS)
            .for_gpu(&h100);
        sweep.threads = opts.threads;
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        let rows = sweep.sweep(&w, &LAMBDAS);

        let mut t = Table::new(&["λ (req/s)", "GPUs", "Cost/yr",
                                 "provision more before λ ="])
            .with_title(format!(
                "GPU step thresholds, H100 two-pool fleet (Azure, \
                 SLO={SLO_MS} ms)"
            ));
        for r in &rows {
            t.row(&[
                format!("{:.0}", r.lambda_rps),
                r.candidate.total_gpus().to_string(),
                dollars(r.cost_yr),
                r.headroom_rps
                    .map(|h| format!("{h:.0}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }

        // The sub-linearity headline.
        let first = rows.first().unwrap();
        let last = rows.last().unwrap();
        let insight = format!(
            "GPU provisioning does not scale linearly with traffic: λ grows \
             {:.0}x ({:.0} -> {:.0} req/s) while the fleet grows {:.1}x \
             ({} -> {} GPUs). The whatif sweep gives the exact step \
             thresholds so capacity stays ahead of demand.",
            last.lambda_rps / first.lambda_rps,
            first.lambda_rps,
            last.lambda_rps,
            last.candidate.total_gpus() as f64
                / first.candidate.total_gpus() as f64,
            first.candidate.total_gpus(),
            last.candidate.total_gpus(),
        );
        PuzzleReport { id: 4, title: self.title().into(), tables: vec![t],
                       insight }
    }
}

/// Legacy entry point (CLI `puzzle 4`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    StepThresholds.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_table_is_monotone_and_sublinear() {
        let report = run(&ScenarioOpts::fast());
        let body = report.tables[0].render();
        assert!(body.contains("25"), "{body}");
        assert!(report.insight.contains("does not scale linearly"));
    }
}
