//! The `retry_storm` scenario (report id 12): when do client retries
//! turn a transient outage into a sustained one — and does a circuit
//! breaker get the fleet back?
//!
//! The classic metastability failure (Bronson et al., HotOS '21;
//! paper §2.3): a fleet sized to pass its SLO with headroom suffers a
//! short full outage. Open-loop, the backlog drains and the fleet
//! recovers. Closed-loop, every timed-out client retries into the
//! already-saturated queue, the *offered* load multiplies by the
//! retry amplification, and admitted requests that waited too long
//! are wasted work (they hold a slot yet still miss their deadline) —
//! so the overload outlives its trigger. The scenario contrasts three
//! regimes on one fleet:
//!
//! * **A — open loop**: no deadlines, no retries, no outage. The
//!   sizing baseline; every window passes, amplification 1.0.
//! * **B — naive retries + outage**: deadlines and retries
//!   ([`retry_spec`]) with no server-side protection, through a
//!   scripted 60 s full-pool outage. Amplification stays above 1 and
//!   the fleet is still failing windows at the end of the horizon,
//!   long after the outage ended.
//! * **C — retries + circuit breaker**: same clients, same outage,
//!   plus hysteretic admission control ([`breaker_clients`]). Sheds
//!   replace wasted work during the storm, so the queue stays bounded
//!   and the final window passes again.
//!
//! Everything is deterministic — the outage is a fault script, the
//! backoff jitter is a named substream — so the three regimes are
//! bit-identical across engines and shard counts, and the regression
//! test below pins the regime structure, not fragile point values.

use crate::des::engine::SimPool;
use crate::des::faults::{FaultScript, GpuFailure};
use crate::des::metrics::DesResult;
use crate::des::retry::{AdmissionSpec, RetryConfig, RetrySpec};
use crate::optimizer::engine::EvalEngine;
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::Table;
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Arrival rate (req/s); with [`MIN_REQUESTS`] this gives a >= 100 s
/// horizon, leaving 20 s of post-outage traffic to expose (non-)
/// recovery.
pub const LAMBDA_RPS: f64 = 100.0;
pub const SLO_MS: f64 = 500.0;
pub const WINDOW_MS: f64 = 5_000.0;
/// Token cap on the Azure CDF: bounds the slowest decode so a healthy
/// fleet never collides with the client deadline (worst hold at the
/// batch cap ~ 1.9 s << [`retry_spec`]'s 8 s timeout).
pub const MAX_CTX: f64 = 1_024.0;
/// Per-instance batch cap: keeps `t_iter` (and thus worst-case hold)
/// small enough that timeouts under regime A are impossible.
pub const BATCH_CAP: u32 = 16;
/// The scripted full-pool outage window.
pub const OUTAGE_START_MS: f64 = 20_000.0;
pub const OUTAGE_END_MS: f64 = 80_000.0;
/// Floor on the request count: the storm needs the full
/// outage + recovery timeline inside the horizon even under `--fast`.
pub const MIN_REQUESTS: usize = 10_000;

/// Azure trace truncated to [`MAX_CTX`] tokens at [`LAMBDA_RPS`].
pub fn workload() -> WorkloadSpec {
    WorkloadSpec::builtin(BuiltinTrace::Azure, LAMBDA_RPS)
        .truncated(MAX_CTX)
        .expect("azure CDF truncates at 1024 tokens")
}

/// The client policy shared by regimes B and C: 8 s deadlines, up to
/// 4 attempts, exponential backoff 1 s -> 8 s with jitter.
pub fn retry_spec() -> RetrySpec {
    RetrySpec {
        max_attempts: 4,
        timeout_ms: 8_000.0,
        backoff_base_ms: 1_000.0,
        backoff_cap_ms: 8_000.0,
    }
}

/// Regime B: clients retry, the server defends nothing.
pub fn naive_clients() -> RetryConfig {
    RetryConfig { retry: Some(retry_spec()), admission: None }
}

/// Regime C: same clients plus the hysteretic breaker (opens at queue
/// depth 32, closes at 8) and a depth-64 queue bound backstop.
pub fn breaker_clients() -> RetryConfig {
    RetryConfig {
        retry: Some(retry_spec()),
        admission: Some(AdmissionSpec {
            max_queue_depth: 64,
            breaker_open_depth: 32,
            breaker_close_depth: 8,
        }),
    }
}

/// The scripted outage: every one of the pool's `n_gpus` instances is
/// down for `[OUTAGE_START_MS, OUTAGE_END_MS)`, instant re-warm (the
/// metastability must come from the clients, not a cold start).
pub fn outage(n_gpus: usize) -> FaultScript {
    FaultScript {
        failures: vec![GpuFailure {
            pool: 0,
            n_gpus,
            start_ms: OUTAGE_START_MS,
            recover_ms: OUTAGE_END_MS,
            warm_ms: 0.0,
            warm_factor: 1.0,
        }],
        stragglers: vec![],
    }
}

/// The three regime runs on the minimal SLO-feasible fleet, or None
/// if no fleet within `opts.max_gpus` passes every window open-loop.
pub struct StormRuns {
    pub n_gpus: u32,
    /// Regime A: open loop, no outage.
    pub baseline: DesResult,
    /// Regime B: naive retries through the outage.
    pub naive: DesResult,
    /// Regime C: retries + circuit breaker through the outage.
    pub breaker: DesResult,
}

/// Size the smallest fleet whose open-loop run passes every window,
/// then replay the two closed-loop regimes on exactly that fleet.
/// Minimal headroom is the point: it is what makes regime B
/// metastable instead of merely slow to drain.
pub fn run_storm(
    engine: &EvalEngine,
    opts: &ScenarioOpts,
) -> Option<StormRuns> {
    let w = workload();
    let mut cfg = opts.des();
    cfg.n_requests = opts.n_requests.max(MIN_REQUESTS);
    if cfg.window_ms.is_none() {
        cfg.window_ms = Some(WINDOW_MS);
    }
    let router = RoutingPolicy::Random { n_pools: 1 };
    let pool = |n: u32| SimPool {
        gpu: engine.catalog.get("H100").unwrap().clone(),
        n_gpus: n as usize,
        ctx_budget: w.cdf.max_len(),
        batch_cap: Some(BATCH_CAP),
    };
    let mut sized: Option<(u32, DesResult)> = None;
    for n in 2..=opts.max_gpus {
        let mut r = engine
            .simulate_robust(&w, &[pool(n)], &router, &cfg, None, None);
        if r.meets_slo_in_every_window(SLO_MS) {
            sized = Some((n, r));
            break;
        }
    }
    let (n, baseline) = sized?;
    let script = outage(n as usize);
    let naive = engine.simulate_robust(
        &w, &[pool(n)], &router, &cfg, Some(&script),
        Some(&naive_clients()),
    );
    let breaker = engine.simulate_robust(
        &w, &[pool(n)], &router, &cfg, Some(&script),
        Some(&breaker_clients()),
    );
    Some(StormRuns { n_gpus: n, baseline, naive, breaker })
}

/// Whether the run's final window — the last 5 s of arrivals, 15 s
/// after the outage ended — meets the SLO. The recovery verdict.
pub fn last_window_ok(r: &mut DesResult, slo_ms: f64) -> bool {
    let w = r.windows.as_mut().expect("windowed run");
    let last = w.n_windows() - 1;
    w.meets_slo(last, slo_ms)
}

fn failed_windows(r: &mut DesResult, slo_ms: f64) -> usize {
    let w = r.windows.as_mut().expect("windowed run");
    (0..w.n_windows()).filter(|&i| !w.meets_slo(i, slo_ms)).count()
}

/// Registry entry for the retry-storm metastability scenario.
pub struct RetryStorm;

impl Scenario for RetryStorm {
    fn id(&self) -> &'static str {
        "retry_storm"
    }

    fn name(&self) -> &'static str {
        "retry-storm"
    }

    fn title(&self) -> &'static str {
        "Retry storm: metastable overload vs circuit-breaker recovery"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", LAMBDA_RPS)],
            gpus: vec!["H100"],
            thresholds: vec![],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "Random",
            topology: Topology::SinglePool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let Some(mut runs) = run_storm(engine, opts) else {
            return PuzzleReport {
                id: 12,
                title: self.title().into(),
                tables: vec![],
                insight: format!(
                    "No H100 fleet within max_gpus = {} passes every \
                     window at {LAMBDA_RPS} req/s; raise max_gpus to \
                     stage the storm.",
                    opts.max_gpus
                ),
            };
        };
        let mut table = Table::new(&[
            "regime", "goodput rps", "offered rps", "amplification",
            "abandoned", "shed", "windows failed", "last window",
        ])
        .with_title(format!(
            "Retry storm on {} H100s (azure@{LAMBDA_RPS:.0}rps <= \
             {MAX_CTX:.0} tokens, full-pool outage [{:.0}, {:.0}) s, \
             SLO {SLO_MS:.0} ms, {WINDOW_MS:.0} ms windows)",
            runs.n_gpus,
            OUTAGE_START_MS / 1000.0,
            OUTAGE_END_MS / 1000.0,
        ));
        let mut amp_b = 0.0;
        for (label, r) in [
            ("A: open loop, no outage", &mut runs.baseline),
            ("B: naive retries + outage", &mut runs.naive),
            ("C: retries + breaker + outage", &mut runs.breaker),
        ] {
            if label.starts_with('B') {
                amp_b = r.retry_amplification();
            }
            table.row(&[
                label.to_string(),
                format!("{:.1}", r.goodput_rps()),
                format!("{:.1}", r.throughput_rps()),
                format!("{:.2}x", r.retry_amplification()),
                r.n_abandoned.to_string(),
                r.n_shed.to_string(),
                failed_windows(r, SLO_MS).to_string(),
                check(last_window_ok(r, SLO_MS)).to_string(),
            ]);
        }
        let recovered = last_window_ok(&mut runs.breaker, SLO_MS);
        PuzzleReport {
            id: 12,
            title: self.title().into(),
            tables: vec![table],
            insight: format!(
                "The same fleet, the same 60 s outage: with naive \
                 retries the offered load is {amp_b:.2}x the demand \
                 and the fleet is {} windows past recovery — \
                 metastable failure sustained by its own clients. The \
                 circuit breaker converts queue waits into cheap sheds \
                 ({} requests turned away), keeps admitted work inside \
                 its deadline, and the final window {}. Server-side \
                 admission control, not client patience, is what ends \
                 a retry storm.",
                failed_windows(&mut runs.naive, SLO_MS),
                runs.breaker.n_shed,
                if recovered { "passes again" } else { "still fails" },
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::default_engine;

    #[test]
    fn storm_shows_three_regimes() {
        let opts = ScenarioOpts::fast();
        let engine = default_engine(&opts);
        let mut runs = run_storm(&engine, &opts).expect("feasible fleet");
        let n_req = opts.n_requests.max(MIN_REQUESTS);

        // Regime A: healthy baseline. Every window passes, nothing is
        // dropped, amplification is exactly 1 (open loop).
        assert!(runs.baseline.meets_slo_in_every_window(SLO_MS));
        assert_eq!(runs.baseline.retry_amplification(), 1.0);
        assert_eq!(runs.baseline.n_abandoned + runs.baseline.n_shed, 0);

        // Regime B: metastable. Retries amplify offered load well past
        // demand, requests die of old age, and the fleet is *still*
        // failing at the end of the horizon — 15+ s after recovery.
        let amp_b = runs.naive.retry_amplification();
        assert!(amp_b > 1.5, "amplification {amp_b}");
        assert!(runs.naive.n_abandoned > 0);
        assert!(!last_window_ok(&mut runs.naive, SLO_MS),
                "naive retries must not have recovered by the horizon");
        assert!(runs.naive.goodput_rps() < runs.naive.throughput_rps());
        assert_eq!(
            runs.naive.overall.count + runs.naive.n_abandoned
                + runs.naive.n_shed + runs.naive.n_unserved,
            n_req,
            "closed-loop conservation (B)"
        );

        // Regime C: the breaker sheds instead of queueing, amplification
        // collapses toward 1, and the final window passes again.
        let amp_c = runs.breaker.retry_amplification();
        assert!(runs.breaker.n_shed > 0);
        assert!(amp_c < amp_b, "breaker must damp amplification");
        assert!(last_window_ok(&mut runs.breaker, SLO_MS),
                "breaker regime must recover by the final window");
        assert_eq!(
            runs.breaker.overall.count + runs.breaker.n_abandoned
                + runs.breaker.n_shed + runs.breaker.n_unserved,
            n_req,
            "closed-loop conservation (C)"
        );

        // The report renders one row per regime.
        let report = RetryStorm.run(&engine, &opts);
        assert_eq!(report.id, 12);
        assert_eq!(report.tables.len(), 1);
        let body = report.tables[0].render();
        assert!(body.contains("A: open loop"), "{body}");
        assert!(body.contains("B: naive retries"), "{body}");
        assert!(body.contains("C: retries + breaker"), "{body}");
        assert!(report.insight.contains("circuit breaker"));
    }
}
