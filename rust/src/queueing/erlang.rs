//! Erlang-B and Erlang-C (paper Eq. 1), numerically stable at large c.
//!
//! This is the pure-rust twin of the L1 Pallas kernel
//! (`python/compile/kernels/erlang.py`): the same Erlang-B recurrence, with
//! early termination at k == c instead of the kernel's fixed-length masked
//! loop. `rust/tests/runtime_parity.rs` cross-validates the two paths
//! through the AOT artifact.

/// Maximum server count the planner sweeps (matches the kernel's C_MAX).
pub const C_MAX: usize = 512;

/// Erlang-B blocking probability B(c, a) for offered load `a = c * rho`.
///
/// Uses the stable recurrence `B_k = a B_{k-1} / (k + a B_{k-1})`.
pub fn erlang_b(a: f64, c: usize) -> f64 {
    assert!(c >= 1, "need at least one server");
    let mut b = 1.0;
    for k in 1..=c {
        b = a * b / (k as f64 + a * b);
    }
    b
}

/// Erlang-C waiting probability C(c, rho) (paper Eq. 1): the probability an
/// arriving request finds all c servers busy. Returns 1.0 when unstable
/// (rho >= 1), 0.0 at zero load.
pub fn erlang_c(rho: f64, c: usize) -> f64 {
    if rho <= 0.0 {
        return 0.0;
    }
    if rho >= 1.0 {
        return 1.0;
    }
    let a = rho * c as f64;
    let b = erlang_b(a, c);
    let denom = 1.0 - rho * (1.0 - b);
    (b / denom).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn erlang_c_direct(rho: f64, c: usize) -> f64 {
        // Textbook summation in f64 (small c only).
        let a = rho * c as f64;
        let mut fact = 1.0;
        let mut sum = 0.0;
        for k in 0..c {
            if k > 0 {
                fact *= k as f64;
            }
            sum += a.powi(k as i32) / fact;
        }
        let cfact = fact * c as f64;
        let top = a.powi(c as i32) / (cfact * (1.0 - rho));
        top / (sum + top)
    }

    #[test]
    fn mm1_reduces_to_rho() {
        for rho in [0.05, 0.3, 0.6, 0.9, 0.99] {
            assert!((erlang_c(rho, 1) - rho).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_direct_summation() {
        for c in [2, 3, 5, 10, 20, 40, 60] {
            for rho in [0.1, 0.4, 0.7, 0.9, 0.97] {
                let got = erlang_c(rho, c);
                let want = erlang_c_direct(rho, c);
                assert!(
                    (got - want).abs() < 1e-9,
                    "c={c} rho={rho}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn erlang_b_known_values() {
        // B(1, a) = a/(1+a).
        for a in [0.1, 1.0, 5.0] {
            assert!((erlang_b(a, 1) - a / (1.0 + a)).abs() < 1e-12);
        }
        // Classic telephony value: B(10, 5) ~ 0.018385.
        assert!((erlang_b(5.0, 10) - 0.018385).abs() < 1e-5);
    }

    #[test]
    fn boundary_behavior() {
        assert_eq!(erlang_c(0.0, 8), 0.0);
        assert_eq!(erlang_c(1.0, 8), 1.0);
        assert_eq!(erlang_c(2.5, 8), 1.0);
    }

    #[test]
    fn stable_at_large_c() {
        // c = 512 at high rho: must not overflow or go negative.
        let v = erlang_c(0.97, C_MAX);
        assert!((0.0..=1.0).contains(&v));
        assert!(v > 0.0);
        // And decreasing in c.
        assert!(erlang_c(0.8, 512) < erlang_c(0.8, 64));
    }

    #[test]
    fn monotone_in_rho() {
        let mut prev = 0.0;
        for i in 1..100 {
            let rho = i as f64 / 100.0;
            let v = erlang_c(rho, 16);
            assert!(v >= prev);
            prev = v;
        }
    }
}
