//! Analytical M/G/c queueing (paper §2.2): Erlang-B/C, Kimura's two-moment
//! approximation, and the per-pool model that integrates the GPU service
//! model over a workload CDF slice.

pub mod erlang;
pub mod kimura;
pub mod mgc;
