//! Per-pool M/G/c analysis (paper §3.1 Phase-1 steps 2–3).
//!
//! A pool is `n` identical GPUs serving the slice of the workload whose
//! total token budget falls in `(lo, hi]`. This module integrates the GPU
//! service model (Eq. 4) over the workload histogram restricted to that
//! slice, then evaluates Kimura's W99 (Eq. 2) and the TTFT decomposition
//! (Eq. 5). It is the rust-native twin of the L2 JAX model
//! (`python/compile/model.py`); `rust/tests/runtime_parity.rs` checks the
//! two agree through the AOT artifact.

use crate::gpu::profile::GpuProfile;
use crate::queueing::erlang::C_MAX;
use crate::queueing::kimura;
use crate::workload::cdf::EmpiricalCdf;

/// Utilization cap for queueing stability (paper §3.1): rho <= 0.85.
pub const RHO_MAX: f64 = 0.85;

/// A pool under analysis: GPU type, count, and the context budget its KV
/// cache is provisioned for (the upper end of its length range).
#[derive(Debug, Clone)]
pub struct PoolSpec {
    pub gpu: GpuProfile,
    pub n_gpus: usize,
    /// Max token budget a sequence in this pool may need (drives n_max).
    pub ctx_budget: f64,
}

/// Results of analyzing one pool.
#[derive(Debug, Clone)]
pub struct PoolAnalysis {
    /// Fraction of total traffic routed to this pool.
    pub alpha: f64,
    /// Pool arrival rate, req/ms.
    pub lambda_ms: f64,
    /// Mean service time E[S] (Eq. 4), ms.
    pub es_ms: f64,
    /// Squared coefficient of variation of service time.
    pub cs2: f64,
    /// Per-server utilization.
    pub rho: f64,
    /// P99 queue wait (Eq. 2), ms.
    pub w99_ms: f64,
    /// P99 prefill latency within the pool, ms.
    pub prefill99_ms: f64,
    /// P99 TTFT (Eq. 5), ms.
    pub ttft99_ms: f64,
    /// rho < 1 (queue does not grow without bound).
    pub stable: bool,
}

impl PoolAnalysis {
    /// Empty pool: no traffic, no latency.
    pub fn empty() -> Self {
        PoolAnalysis {
            alpha: 0.0,
            lambda_ms: 0.0,
            es_ms: 0.0,
            cs2: 0.0,
            rho: 0.0,
            w99_ms: 0.0,
            prefill99_ms: 0.0,
            ttft99_ms: 0.0,
            stable: true,
        }
    }

    /// Meets the SLO under the utilization cap (paper §3.1 step 3).
    pub fn meets_slo(&self, slo_ms: f64) -> bool {
        self.alpha <= 1e-12
            || (self.stable && self.rho <= RHO_MAX && self.ttft99_ms <= slo_ms)
    }
}

/// The planner's standard histogram resolution (matches the AOT artifact).
pub const K_BINS: usize = 256;

/// A discretized workload shared across many pool evaluations.
#[derive(Debug, Clone)]
pub struct WorkloadHist {
    pub probs: Vec<f64>,
    pub lens: Vec<f64>,
    pub input_frac: f64,
}

impl WorkloadHist {
    pub fn from_cdf(cdf: &EmpiricalCdf, input_frac: f64) -> Self {
        let (probs, lens) = cdf.histogram(K_BINS);
        WorkloadHist { probs, lens, input_frac }
    }

    /// Fraction of requests with budget in (lo, hi].
    pub fn mass(&self, lo: f64, hi: f64) -> f64 {
        self.probs
            .iter()
            .zip(&self.lens)
            .filter(|(_, &l)| l > lo && l <= hi)
            .map(|(p, _)| p)
            .sum()
    }

    /// Conditional q-quantile of the budget within (lo, hi].
    pub fn conditional_quantile(&self, lo: f64, hi: f64, q: f64) -> f64 {
        let alpha = self.mass(lo, hi);
        if alpha <= 1e-12 {
            return 0.0;
        }
        let target = q * alpha;
        let mut cum = 0.0;
        for (p, &l) in self.probs.iter().zip(&self.lens) {
            if l > lo && l <= hi {
                cum += p;
                if cum >= target {
                    return l;
                }
            }
        }
        hi
    }

    /// Split a bin's budget into (prompt, completion) tokens.
    fn split(&self, total: f64) -> (f64, f64) {
        let l_in = (total * self.input_frac).ceil();
        let l_out = (total - l_in).max(1.0);
        (l_in, l_out)
    }
}

/// Equilibrium concurrency per GPU (Little's law on the linear t_iter).
///
/// Demand of `a` tokens/ms/GPU with t_iter(n) = W + H n self-consistently
/// settles at n̄ = a W / (1 - a H), clamped to [1, n_eff]. Above the
/// token-throughput ceiling (a H >= 1) the batch saturates at n_eff.
/// This is the recalibration the paper applies in §4.8 ("the M/G/c
/// service rate is recalibrated at each batch cap") and is what makes the
/// analytic TTFT independent of the cap while n̄ stays below it (Table 9's
/// constant 0-30%-flex column).
pub fn equilibrium_batch(gpu: &crate::gpu::profile::GpuProfile,
                         n_eff: f64, tokens_per_ms_per_gpu: f64) -> f64 {
    let a = tokens_per_ms_per_gpu;
    if a <= 0.0 {
        return 1.0;
    }
    if a * gpu.h_ms_per_slot >= 1.0 {
        return n_eff;
    }
    (a * gpu.w_ms / (1.0 - a * gpu.h_ms_per_slot)).clamp(1.0, n_eff)
}

/// Analyze one pool serving the (lo, hi] slice of the workload.
///
/// `lambda_total_ms` is the *fleet-wide* arrival rate in req/ms; the pool
/// receives `alpha x lambda` per the deterministic length split
/// (paper §3.1 step 1, with the §3.3 sub-stream Poisson caveat).
///
/// Service times follow Eq. 4 with the iteration latency evaluated at the
/// pool's equilibrium concurrency n̄ (see [`equilibrium_batch`]):
/// `E[S] = iters / n_eff * t_iter(n̄)`. Utilization rho = lambda E[S] / c
/// then equals the slot-occupancy fraction n̄ / n_eff, and the slot-count
/// advantage of a short pool translates into real throughput — the §2.1
/// "cost cliff" mechanism.
pub fn analyze_pool(
    hist: &WorkloadHist,
    lo: f64,
    hi: f64,
    lambda_total_ms: f64,
    spec: &PoolSpec,
) -> PoolAnalysis {
    let alpha = hist.mass(lo, hi);
    if alpha <= 1e-12 {
        return PoolAnalysis::empty();
    }
    let n = spec.gpu.n_eff(spec.ctx_budget);
    let lambda_ms = lambda_total_ms * alpha;
    let c = spec.n_gpus.clamp(1, C_MAX);

    // Conditional iteration-count moments over the slice.
    let mut i1 = 0.0;
    let mut i2 = 0.0;
    for (p, &l) in hist.probs.iter().zip(&hist.lens) {
        if l > lo && l <= hi {
            let (l_in, l_out) = hist.split(l);
            let it = spec.gpu.iters(l_in, l_out);
            i1 += p * it;
            i2 += p * it * it;
        }
    }
    i1 /= alpha;
    i2 /= alpha;
    // S = iters * t̄ / n_eff: the constant factor cancels in Cs².
    let cs2 = (i2 / (i1 * i1) - 1.0).max(0.0);

    let tokens_per_ms_per_gpu = lambda_ms * i1 / c as f64;
    let n_bar = equilibrium_batch(&spec.gpu, n, tokens_per_ms_per_gpu);
    let t_bar = spec.gpu.t_iter(n_bar);
    let es = i1 * t_bar / n;
    let rho = lambda_ms * es / c as f64;
    let w99 = kimura::w99(rho, c, es, cs2);

    // P99 prefill: chunked prefill of the pool's P99 prompt (Eq. 5) at the
    // equilibrium iteration latency.
    let p99_len = hist.conditional_quantile(lo, hi, 0.99);
    let l_in99 = (p99_len * hist.input_frac).ceil();
    let prefill99 = (l_in99 / spec.gpu.chunk).ceil() * t_bar;
    let ttft99 = w99 + prefill99 + t_bar;

    PoolAnalysis {
        alpha,
        lambda_ms,
        es_ms: es,
        cs2,
        rho,
        w99_ms: w99,
        prefill99_ms: prefill99,
        ttft99_ms: ttft99,
        stable: rho < 1.0,
    }
}

/// Convenience: the paper's two-pool analysis — short pool (0, B] and long
/// pool (B, max]. Returns (short, long).
pub fn analyze_two_pool(
    hist: &WorkloadHist,
    b_short: f64,
    max_len: f64,
    lambda_total_ms: f64,
    short: &PoolSpec,
    long: &PoolSpec,
) -> (PoolAnalysis, PoolAnalysis) {
    (
        analyze_pool(hist, 0.0, b_short, lambda_total_ms, short),
        analyze_pool(hist, b_short, max_len, lambda_total_ms, long),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::builtin::Trace;

    fn a100() -> GpuProfile {
        GpuCatalog::standard().get("A100").unwrap().clone()
    }

    use crate::gpu::profile::GpuProfile;

    fn lmsys_hist() -> WorkloadHist {
        let t = Trace::lmsys();
        WorkloadHist::from_cdf(&t.cdf, t.input_fraction)
    }

    #[test]
    fn mass_matches_cdf() {
        let h = lmsys_hist();
        let alpha = h.mass(0.0, 4096.0);
        assert!((alpha - 0.984).abs() < 0.01, "alpha = {alpha}");
        assert!((h.mass(0.0, 1e9) - 1.0).abs() < 1e-9);
        assert!((h.mass(0.0, 4096.0) + h.mass(4096.0, 1e9) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn conditional_quantile_in_range() {
        let h = lmsys_hist();
        let q = h.conditional_quantile(4096.0, 65536.0, 0.99);
        assert!(q > 4096.0 && q <= 65536.0, "q = {q}");
        let qs = h.conditional_quantile(0.0, 4096.0, 0.99);
        assert!(qs <= 4096.0);
        assert_eq!(h.conditional_quantile(1e8, 1e9, 0.99), 0.0);
    }

    #[test]
    fn empty_pool_is_feasible() {
        let h = lmsys_hist();
        let spec = PoolSpec { gpu: a100(), n_gpus: 1, ctx_budget: 65536.0 };
        let a = analyze_pool(&h, 1e8, 1e9, 0.1, &spec);
        assert_eq!(a.alpha, 0.0);
        assert!(a.meets_slo(1.0));
    }

    #[test]
    fn overload_is_unstable_and_fails_slo() {
        let h = lmsys_hist();
        let spec = PoolSpec { gpu: a100(), n_gpus: 1, ctx_budget: 65536.0 };
        let a = analyze_pool(&h, 0.0, 1e9, 1.0, &spec); // 1000 req/s on 1 GPU
        assert!(!a.stable);
        assert!(a.w99_ms.is_infinite());
        assert!(!a.meets_slo(1e9));
    }

    #[test]
    fn more_gpus_reduce_rho_and_wait() {
        // Under the equilibrium-batch model rho falls *faster* than 1/c
        // (fewer GPUs -> higher per-GPU concurrency -> slower iterations).
        let h = lmsys_hist();
        let mk = |n| PoolSpec { gpu: a100(), n_gpus: n, ctx_budget: 65536.0 };
        let a4 = analyze_pool(&h, 0.0, 1e9, 0.05, &mk(4));
        let a8 = analyze_pool(&h, 0.0, 1e9, 0.05, &mk(8));
        assert!(a4.rho / a8.rho >= 2.0 - 1e-9, "{} vs {}", a4.rho, a8.rho);
        assert!(a8.w99_ms < a4.w99_ms);
    }

    #[test]
    fn short_pool_has_lower_service_time() {
        let h = lmsys_hist();
        let short = PoolSpec { gpu: a100(), n_gpus: 3, ctx_budget: 4096.0 };
        let long = PoolSpec { gpu: a100(), n_gpus: 5, ctx_budget: 65536.0 };
        let (s, l) = analyze_two_pool(&h, 4096.0, 65536.0, 0.1, &short, &long);
        assert!(s.es_ms < l.es_ms / 5.0, "es_s={} es_l={}", s.es_ms, l.es_ms);
        assert!((s.alpha + l.alpha - 1.0).abs() < 1e-9);
        // Short pool gets the 16x slot advantage (§4.1): 256 vs 16 slots.
        assert_eq!(short.gpu.n_max(4096.0), 256.0);
        assert_eq!(long.gpu.n_max(65536.0), 16.0);
    }

    #[test]
    fn prefill_dominates_for_long_context_low_load() {
        // Long pool at trivial load: TTFT ~ prefill, not queueing.
        let h = lmsys_hist();
        let long = PoolSpec { gpu: a100(), n_gpus: 8, ctx_budget: 65536.0 };
        let a = analyze_pool(&h, 4096.0, 65536.0, 0.001, &long);
        assert!(a.w99_ms < 1.0, "w99 = {}", a.w99_ms);
        assert!(a.prefill99_ms > 100.0, "prefill = {}", a.prefill99_ms);
        assert!((a.ttft99_ms - a.prefill99_ms - a.w99_ms).abs() < 20.0);
    }

    #[test]
    fn meets_slo_respects_rho_cap() {
        // Direct check of the feasibility predicate: a stable pool above
        // the utilization cap must be rejected regardless of SLO.
        let a = PoolAnalysis {
            alpha: 0.5,
            lambda_ms: 0.1,
            es_ms: 10.0,
            cs2: 1.0,
            rho: 0.9,
            w99_ms: 5.0,
            prefill99_ms: 5.0,
            ttft99_ms: 12.0,
            stable: true,
        };
        assert!(!a.meets_slo(1e9));
        let ok = PoolAnalysis { rho: 0.8, ..a.clone() };
        assert!(ok.meets_slo(1e9));
        assert!(!ok.meets_slo(1.0)); // ttft 12 > 1
    }

    #[test]
    fn agent_workload_has_high_cs2() {
        // Heavy-tailed agent trace: service-time SCV across the whole
        // range must be large (the Puzzle-2 mechanism).
        let t = Trace::agent();
        let h = WorkloadHist::from_cdf(&t.cdf, t.input_fraction);
        let h100 = GpuCatalog::standard().get("H100").unwrap().clone();
        let spec = PoolSpec { gpu: h100, n_gpus: 24, ctx_budget: 300000.0 };
        let a = analyze_pool(&h, 0.0, 1e9, 0.02, &spec);
        assert!(a.cs2 > 3.0, "cs2 = {}", a.cs2);
    }
}
