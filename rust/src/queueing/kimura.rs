//! Kimura's two-moment M/G/c approximation (paper Eq. 2).
//!
//! The P-th percentile queue wait of an M/G/c queue with mean service E[S],
//! squared coefficient of variation Cs², and per-server utilization rho:
//!
//! ```text
//! W_p ≈ C(c, rho) / (c µ (1 - rho)) · (1 + Cs²)/2 · ln(1/(1-p))
//! ```
//!
//! (the paper prints the p = 0.99 case, ln(100)). The (1+Cs²)/2 factor is
//! the Pollaczek–Khinchine correction that M/M/c lacks; for heavy-tailed
//! agent workloads even this under-estimates the tail, which is why Phase 2
//! exists (paper §3.2 "Model fidelity", §4.2).

use crate::queueing::erlang::erlang_c;

/// Mean queue wait (ms) under the two-moment approximation.
pub fn mean_wait(rho: f64, c: usize, es_ms: f64, cs2: f64) -> f64 {
    if rho <= 0.0 {
        return 0.0;
    }
    if rho >= 1.0 {
        return f64::INFINITY;
    }
    let pc = erlang_c(rho, c);
    let c_mu = c as f64 / es_ms;
    pc / (c_mu * (1.0 - rho)) * (1.0 + cs2) / 2.0
}

/// P-th percentile queue wait (ms), `p` in (0, 1).
pub fn percentile_wait(
    rho: f64,
    c: usize,
    es_ms: f64,
    cs2: f64,
    p: f64,
) -> f64 {
    assert!((0.0..1.0).contains(&p));
    let w = mean_wait(rho, c, es_ms, cs2);
    if !w.is_finite() {
        return w;
    }
    w * (1.0 / (1.0 - p)).ln()
}

/// The paper's headline metric: P99 queue wait (Eq. 2).
pub fn w99(rho: f64, c: usize, es_ms: f64, cs2: f64) -> f64 {
    percentile_wait(rho, c, es_ms, cs2, 0.99)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_service_matches_mmc_mean() {
        // With Cs² = 1 the formula reduces to the exact M/M/c mean wait
        // W = C(c,rho) / (c mu (1 - rho)).
        let (rho, c, es) = (0.8, 4, 100.0);
        let w = mean_wait(rho, c, es, 1.0);
        let want = erlang_c(rho, c) / (c as f64 / es * (1.0 - rho));
        assert!((w - want).abs() < 1e-12);
    }

    #[test]
    fn w99_is_ln100_times_mean() {
        let (rho, c, es, cs2) = (0.7, 8, 50.0, 3.0);
        let w = w99(rho, c, es, cs2);
        assert!((w / mean_wait(rho, c, es, cs2) - 100.0f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn deterministic_service_halves_exponential_wait() {
        // Cs² = 0 -> (1+0)/2 = half the exponential-service wait.
        let (rho, c, es) = (0.8, 2, 10.0);
        let diff =
            mean_wait(rho, c, es, 0.0) * 2.0 - mean_wait(rho, c, es, 1.0);
        assert!(diff.abs() < 1e-12);
    }

    #[test]
    fn unstable_is_infinite() {
        assert!(w99(1.0, 4, 10.0, 1.0).is_infinite());
        assert!(w99(1.7, 4, 10.0, 1.0).is_infinite());
    }

    #[test]
    fn zero_load_is_zero_wait() {
        assert_eq!(w99(0.0, 4, 10.0, 1.0), 0.0);
    }

    #[test]
    fn heavy_tail_correction_scales_linearly() {
        // Doubling (1 + Cs²) doubles the predicted wait.
        let base = w99(0.6, 8, 20.0, 1.0);
        let heavy = w99(0.6, 8, 20.0, 3.0);
        assert!((heavy / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn wait_explodes_near_saturation() {
        let w85 = w99(0.85, 16, 30.0, 1.0);
        let w99v = w99(0.99, 16, 30.0, 1.0);
        assert!(w99v > w85 * 20.0, "{w85} -> {w99v}");
    }
}
