//! Command-line interface: argument parsing (offline substrate for clap)
//! and subcommand implementations.

pub mod args;
pub mod commands;
