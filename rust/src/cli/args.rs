//! Minimal argument parser (the `clap` crate is unavailable offline).
//!
//! Grammar: `fleet-sim <subcommand> [positional ...] [--key value]
//! [--flag]`. Flags are distinguished from valued options by the
//! subcommand's declaration.

use std::collections::BTreeMap;

/// Parsed invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]); `flag_names` lists boolean flags.
    pub fn parse(
        argv: &[String],
        flag_names: &[&str],
    ) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(sub) = it.next() {
            args.subcommand = sub.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    args.flags.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--{name} needs a value")
                    })?;
                    args.options.insert(name.to_string(), v.clone());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{name}: '{v}' is not a number")
            }),
        }
    }

    pub fn get_usize(
        &self,
        name: &str,
        default: usize,
    ) -> anyhow::Result<usize> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{name}: '{v}' is not an integer")
            }),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, name: &str, default: &[f64])
        -> anyhow::Result<Vec<f64>>
    {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim().parse().map_err(|_| {
                        anyhow::anyhow!("--{name}: bad number '{x}'")
                    })
                })
                .collect(),
        }
    }
}

/// The simulation-input flag group shared by every DES-driving
/// subcommand: `--requests`, `--seed`, `--shards`, `--chunk-size`,
/// `--window`, an optional `--faults <path>` TOML fault script
/// ([`crate::des::faults`]), an optional `--retries <path>`
/// closed-loop client config ([`crate::des::retry`]), and an optional
/// `--memory <path>` KV-cache memory model ([`crate::des::memory`]).
/// Parsed once here instead of re-reading the same flags (with subtly
/// different validation) in each command.
///
/// Every field is `None` when its flag was absent, so commands keep
/// their own defaults via the `*_or` accessors; `--window` is validated
/// centrally.
#[derive(Debug, Clone, Default)]
pub struct SimKnobs {
    pub n_requests: Option<usize>,
    pub seed: Option<u64>,
    pub n_shards: Option<usize>,
    pub chunk_size: Option<usize>,
    pub window_ms: Option<f64>,
    pub faults_path: Option<String>,
    pub retries_path: Option<String>,
    pub memory_path: Option<String>,
}

impl SimKnobs {
    /// Extract the group from parsed argv.
    pub fn from_args(args: &Args) -> anyhow::Result<SimKnobs> {
        let opt_usize = |name: &str| -> anyhow::Result<Option<usize>> {
            match args.get(name) {
                None => Ok(None),
                Some(_) => Ok(Some(args.get_usize(name, 0)?)),
            }
        };
        let window_ms = match args.get("window") {
            None => None,
            Some(_) => {
                let w = args.get_f64("window", 0.0)?;
                anyhow::ensure!(
                    w.is_finite() && w >= 1.0,
                    "--window must be a finite width of at least 1 ms"
                );
                Some(w)
            }
        };
        Ok(SimKnobs {
            n_requests: opt_usize("requests")?,
            seed: opt_usize("seed")?.map(|s| s as u64),
            n_shards: opt_usize("shards")?,
            chunk_size: opt_usize("chunk-size")?,
            window_ms,
            faults_path: args.get("faults").map(|s| s.to_string()),
            retries_path: args.get("retries").map(|s| s.to_string()),
            memory_path: args.get("memory").map(|s| s.to_string()),
        })
    }

    pub fn requests_or(&self, default: usize) -> usize {
        self.n_requests.unwrap_or(default)
    }

    pub fn seed_or(&self, default: u64) -> u64 {
        self.seed.unwrap_or(default)
    }

    /// Shard count, clamped to at least 1.
    pub fn shards_or(&self, default: usize) -> usize {
        self.n_shards.unwrap_or(default).max(1)
    }

    /// Generator chunk size, clamped to at least 1.
    pub fn chunk_size_or(&self, default: usize) -> usize {
        self.chunk_size.unwrap_or(default).max(1)
    }

    /// Read and parse the `--faults` TOML script, if one was given.
    /// Pool-range validation happens later, against the actual layout
    /// ([`crate::des::faults::FaultScript::validate`]).
    pub fn load_faults(
        &self,
    ) -> anyhow::Result<Option<crate::des::faults::FaultScript>> {
        let Some(path) = &self.faults_path else {
            return Ok(None);
        };
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--faults {path}: {e}"))?;
        let script = crate::des::faults::FaultScript::from_toml_str(&text)
            .map_err(|e| anyhow::anyhow!("--faults {path}: {e}"))?;
        Ok(Some(script))
    }

    /// Read and parse the `--retries` TOML closed-loop config, if one
    /// was given. Parsing also validates
    /// ([`crate::des::retry::RetryConfig::validate`]), so a config that
    /// loads here is ready to attach to a `SimInput`.
    pub fn load_retries(
        &self,
    ) -> anyhow::Result<Option<crate::des::retry::RetryConfig>> {
        let Some(path) = &self.retries_path else {
            return Ok(None);
        };
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--retries {path}: {e}"))?;
        let cfg = crate::des::retry::RetryConfig::from_toml_str(&text)
            .map_err(|e| anyhow::anyhow!("--retries {path}: {e}"))?;
        Ok(Some(cfg))
    }

    /// Read and parse the `--memory` TOML KV-cache model, if one was
    /// given. Per-pool capacity validation happens later, against the
    /// actual layout
    /// ([`crate::des::memory::MemoryConfig::validate`]).
    pub fn load_memory(
        &self,
    ) -> anyhow::Result<Option<crate::des::memory::MemoryConfig>> {
        let Some(path) = &self.memory_path else {
            return Ok(None);
        };
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--memory {path}: {e}"))?;
        let cfg = crate::des::memory::MemoryConfig::from_toml_str(&text)
            .map_err(|e| anyhow::anyhow!("--memory {path}: {e}"))?;
        Ok(Some(cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &sv(&["plan", "--trace", "azure", "--lambda", "100", "--fast",
                  "3"]),
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "plan");
        assert_eq!(a.get("trace"), Some("azure"));
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 100.0);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["3"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = Args::parse(&sv(&["x", "--slo=250"]), &[]).unwrap();
        assert_eq!(a.get_f64("slo", 0.0).unwrap(), 250.0);
        assert_eq!(a.get_f64("missing", 7.5).unwrap(), 7.5);
        assert_eq!(a.get_str("trace", "lmsys"), "lmsys");
    }

    #[test]
    fn rejects_missing_value_and_bad_numbers() {
        assert!(Args::parse(&sv(&["x", "--slo"]), &[]).is_err());
        let a = Args::parse(&sv(&["x", "--slo", "abc"]), &[]).unwrap();
        assert!(a.get_f64("slo", 0.0).is_err());
    }

    #[test]
    fn sim_knobs_extracts_the_shared_flag_group() {
        let a = Args::parse(
            &sv(&["simulate", "--requests", "5000", "--seed", "7",
                  "--shards", "4", "--chunk-size", "512", "--window",
                  "1000", "--faults", "outage.toml", "--retries",
                  "clients.toml", "--memory", "hbm.toml"]),
            &[],
        )
        .unwrap();
        let k = SimKnobs::from_args(&a).unwrap();
        assert_eq!(k.requests_or(1), 5_000);
        assert_eq!(k.seed_or(0), 7);
        assert_eq!(k.shards_or(1), 4);
        assert_eq!(k.chunk_size_or(1), 512);
        assert_eq!(k.window_ms, Some(1_000.0));
        assert_eq!(k.faults_path.as_deref(), Some("outage.toml"));
        assert_eq!(k.retries_path.as_deref(), Some("clients.toml"));
        assert_eq!(k.memory_path.as_deref(), Some("hbm.toml"));
    }

    #[test]
    fn sim_knobs_defaults_clamps_and_validates() {
        let a = Args::parse(&sv(&["simulate"]), &[]).unwrap();
        let k = SimKnobs::from_args(&a).unwrap();
        assert_eq!(k.requests_or(9), 9);
        assert_eq!(k.seed_or(42), 42);
        assert_eq!(k.shards_or(0), 1); // clamped to >= 1
        assert_eq!(k.chunk_size_or(0), 1);
        assert_eq!(k.window_ms, None);
        assert!(k.load_faults().unwrap().is_none());
        assert!(k.load_retries().unwrap().is_none());
        assert!(k.load_memory().unwrap().is_none());

        let bad = Args::parse(&sv(&["simulate", "--window", "-3"]), &[])
            .unwrap();
        assert!(SimKnobs::from_args(&bad).is_err());

        let gone = Args::parse(
            &sv(&["simulate", "--faults", "/no/such/file.toml"]),
            &[],
        )
        .unwrap();
        let err = SimKnobs::from_args(&gone)
            .unwrap()
            .load_faults()
            .unwrap_err();
        assert!(format!("{err}").contains("--faults"), "{err}");

        let gone = Args::parse(
            &sv(&["simulate", "--retries", "/no/such/clients.toml"]),
            &[],
        )
        .unwrap();
        let err = SimKnobs::from_args(&gone)
            .unwrap()
            .load_retries()
            .unwrap_err();
        assert!(format!("{err}").contains("--retries"), "{err}");

        let gone = Args::parse(
            &sv(&["simulate", "--memory", "/no/such/hbm.toml"]),
            &[],
        )
        .unwrap();
        let err = SimKnobs::from_args(&gone)
            .unwrap()
            .load_memory()
            .unwrap_err();
        assert!(format!("{err}").contains("--memory"), "{err}");
    }

    #[test]
    fn parses_lists() {
        let a = Args::parse(&sv(&["x", "--lambdas", "25,50, 100"]), &[])
            .unwrap();
        assert_eq!(a.get_f64_list("lambdas", &[]).unwrap(),
                   vec![25.0, 50.0, 100.0]);
        assert_eq!(a.get_f64_list("other", &[1.0]).unwrap(), vec![1.0]);
    }
}
