//! Minimal argument parser (the `clap` crate is unavailable offline).
//!
//! Grammar: `fleet-sim <subcommand> [positional ...] [--key value]
//! [--flag]`. Flags are distinguished from valued options by the
//! subcommand's declaration.

use std::collections::BTreeMap;

/// Parsed invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]); `flag_names` lists boolean flags.
    pub fn parse(
        argv: &[String],
        flag_names: &[&str],
    ) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(sub) = it.next() {
            args.subcommand = sub.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    args.flags.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--{name} needs a value")
                    })?;
                    args.options.insert(name.to_string(), v.clone());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{name}: '{v}' is not a number")
            }),
        }
    }

    pub fn get_usize(
        &self,
        name: &str,
        default: usize,
    ) -> anyhow::Result<usize> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{name}: '{v}' is not an integer")
            }),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, name: &str, default: &[f64])
        -> anyhow::Result<Vec<f64>>
    {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim().parse().map_err(|_| {
                        anyhow::anyhow!("--{name}: bad number '{x}'")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &sv(&["plan", "--trace", "azure", "--lambda", "100", "--fast",
                  "3"]),
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "plan");
        assert_eq!(a.get("trace"), Some("azure"));
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 100.0);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["3"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = Args::parse(&sv(&["x", "--slo=250"]), &[]).unwrap();
        assert_eq!(a.get_f64("slo", 0.0).unwrap(), 250.0);
        assert_eq!(a.get_f64("missing", 7.5).unwrap(), 7.5);
        assert_eq!(a.get_str("trace", "lmsys"), "lmsys");
    }

    #[test]
    fn rejects_missing_value_and_bad_numbers() {
        assert!(Args::parse(&sv(&["x", "--slo"]), &[]).is_err());
        let a = Args::parse(&sv(&["x", "--slo", "abc"]), &[]).unwrap();
        assert!(a.get_f64("slo", 0.0).is_err());
    }

    #[test]
    fn parses_lists() {
        let a = Args::parse(&sv(&["x", "--lambdas", "25,50, 100"]), &[])
            .unwrap();
        assert_eq!(a.get_f64_list("lambdas", &[]).unwrap(),
                   vec![25.0, 50.0, 100.0]);
        assert_eq!(a.get_f64_list("other", &[1.0]).unwrap(), vec![1.0]);
    }
}
