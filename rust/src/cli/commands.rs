//! Subcommand implementations for the `fleet-sim` binary.

use crate::cli::args::{Args, SimKnobs};
use crate::des::engine::SimPool;
use crate::gpu::catalog::GpuCatalog;
use crate::optimizer::analytic::{NativeSweep, SweepEval};
use crate::optimizer::disagg::{simulate_disagg, DisaggFleetOptimizer};
use crate::optimizer::gridflex::{grid_flex_analysis, GridFlexConfig};
use crate::optimizer::planner::FleetOptimizer;
use crate::optimizer::reliability::NodeAvail;
use crate::optimizer::whatif::WhatIfSweep;
use crate::report::fidelity::fidelity_table;
use crate::router::RoutingPolicy;
use crate::runtime::sweep::AotSweep;
use crate::scenarios::{self, Scenario, ScenarioOpts};
use crate::util::table::{dollars, millis, Table};
use crate::workload::builtin::Trace;
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const USAGE: &str = "\
inference-fleet-sim — queueing-theory-grounded LLM fleet capacity planner

USAGE: fleet-sim <command> [options]

COMMANDS:
  scenarios   list every registered scenario (id, name, spec summary)
  run         run one scenario by id or name: --scenario <id|name>
              [--fast] [--requests N] [--seed S] [--threads T]
              (registry spans puzzle1..8, multimodel, diurnal, n_plus_k,
              retry_storm, kv_stability)
  plan        two-phase fleet plan: --trace lmsys|azure|agent|<path.json>
              --lambda RPS [--slo MS] [--mixed] [--backend native|aot]
              [--node-avail none|soft|hard|5pct] [--top-k K] [--explain]
  simulate    DES one layout: --trace T --lambda RPS --gpu NAME
              --n-short N --n-long N --b-short TOKENS [--requests N]
              [--router length|compress|random] [--seed S]
              [--window MS [--slo MS]]  (per-window P99/attainment table)
              [--faults PATH]  (deterministic fault script, TOML:
              [[failure]]/[[straggler]] sections; see data/faults/)
              [--retries PATH]  (closed-loop clients: deadlines, retries
              with deterministic backoff, admission control; TOML
              [retry]/[admission] sections; see data/retry/)
              [--memory PATH]  (KV-cache memory model: token-granular
              occupancy, memory-bounded admission, preemption; TOML
              [memory] section; see data/memory/)
  whatif      λ step thresholds: --trace T --gpu NAME
              [--lambdas 25,50,...] [--slo MS]
  disagg      prefill/decode planning: --trace T --lambda RPS
              [--ttft-slo MS] [--tpot-slo MS]
  gridflex    demand-response curve: --trace T --lambda RPS [--gpus N]
              [--slo MS] [--requests N]
  bench       deterministic DES perf harness: times the production
              (calendar-queue) engine against the reference heap engine
              and emits a BENCH_N.json snapshot for the CI perf gate
              [--json] [--out PATH] [--engine production|reference|both]
              [--requests N] [--samples K] [--seed S] [--fast]
              [--scale [--scale-requests N] [--shards N]
               [--chunk-size N]]  (adds the generator-driven sharded
              lmsys_1e8 scenario: 10^8 requests in bounded memory)
  fidelity    Kimura-vs-DES model fidelity table [--requests N]
  ablation    service-model ablation (equilibrium vs n_max t_iter)
  sensitivity synthetic-length sensitivity sweep [--lambda RPS] [--slo MS]
  substream   sub-stream Poisson approximation check (paper §5)
              [--trace T] [--lambda RPS] [--b-short TOKENS]
  multimodel  three-class ModelRouter fleet [--fast]
  puzzle N    regenerate paper case study N (1..8) [--fast]
              (alias for `run --scenario puzzleN`)
  reproduce-all   all eight puzzles [--fast]
  profiles    print the GPU catalog and reliability constants
  selftest-runtime   load artifacts/ and cross-check AOT vs native sweep
";

fn workload_from(args: &Args) -> anyhow::Result<WorkloadSpec> {
    let name = args.get_str("trace", "azure");
    let lambda = args.get_f64("lambda", 100.0)?;
    let spec = match BuiltinTrace::parse(name) {
        Ok(t) => WorkloadSpec::builtin(t, lambda),
        Err(_) => {
            let t = Trace::load(std::path::Path::new(name))?;
            WorkloadSpec::from_trace(&t, lambda)
        }
    };
    match args.get("max-ctx") {
        Some(v) => spec.truncated(v.parse()?),
        None => Ok(spec),
    }
}

fn scenario_opts(args: &Args) -> anyhow::Result<ScenarioOpts> {
    let knobs = SimKnobs::from_args(args)?;
    let mut opts = if args.flag("fast") {
        ScenarioOpts::fast()
    } else {
        ScenarioOpts::default()
    };
    opts.n_requests = knobs.requests_or(opts.n_requests);
    opts.seed = knobs.seed_or(opts.seed);
    opts.threads = args.get_usize("threads", opts.threads)?.max(1);
    if knobs.window_ms.is_some() {
        opts.window_ms = knobs.window_ms;
    }
    Ok(opts)
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    match args.subcommand.as_str() {
        "scenarios" => cmd_scenarios(),
        "run" => cmd_run(args),
        "plan" => cmd_plan(args),
        "simulate" => cmd_simulate(args),
        "whatif" => cmd_whatif(args),
        "disagg" => cmd_disagg(args),
        "gridflex" => cmd_gridflex(args),
        "bench" => cmd_bench(args),
        "fidelity" => cmd_fidelity(args),
        "ablation" => cmd_ablation(args),
        "sensitivity" => cmd_sensitivity(args),
        "substream" => cmd_substream(args),
        "multimodel" => cmd_multimodel(args),
        "puzzle" => cmd_puzzle(args),
        "reproduce-all" => cmd_reproduce_all(args),
        "profiles" => cmd_profiles(),
        "selftest-runtime" => cmd_selftest(),
        "" | "help" | "--help" | "-h" => Ok(USAGE.to_string()),
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_scenarios() -> anyhow::Result<String> {
    let mut t = Table::new(&["id", "name", "title", "spec"])
        .align(&[crate::util::table::Align::Left,
                 crate::util::table::Align::Left,
                 crate::util::table::Align::Left,
                 crate::util::table::Align::Left]);
    for s in scenarios::registry() {
        t.row(&[
            s.id().to_string(),
            s.name().to_string(),
            s.title().to_string(),
            s.spec().summary(),
        ]);
    }
    Ok(format!(
        "{}\nrun one with: fleet-sim run --scenario <id|name> [--fast]\n",
        t.render()
    ))
}

fn cmd_run(args: &Args) -> anyhow::Result<String> {
    let key = args
        .get("scenario")
        .ok_or_else(|| anyhow::anyhow!(
            "usage: fleet-sim run --scenario <id|name> (see `fleet-sim \
             scenarios` for the registry)"))?;
    let scenario = scenarios::find(key).ok_or_else(|| {
        let known: Vec<String> = scenarios::registry()
            .iter()
            .map(|s| format!("{} ({})", s.id(), s.name()))
            .collect();
        anyhow::anyhow!("unknown scenario '{key}'; registered: {}",
                        known.join(", "))
    })?;
    let opts = scenario_opts(args)?;
    let engine = scenarios::default_engine(&opts);
    Ok(scenario.run(&engine, &opts).render())
}

fn cmd_plan(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let slo = args.get_f64("slo", 500.0)?;
    let mut opt = FleetOptimizer::new(GpuCatalog::standard(), slo);
    opt.gen.allow_mixed = args.flag("mixed");
    opt.top_k = args.get_usize("top-k", 8)?;
    opt.des.n_requests = args.get_usize("requests", 10_000)?;
    opt.node_avail = match args.get_str("node-avail", "none") {
        "none" => NodeAvail::default(),
        "soft" => NodeAvail::soft_failure(),
        "hard" => NodeAvail::hard_failure(),
        "5pct" => NodeAvail::five_percent_rule(),
        other => anyhow::bail!("--node-avail: unknown '{other}'"),
    };
    let backend = args.get_str("backend", "native");
    let plan = match backend {
        "native" => opt.plan(&w),
        "aot" => {
            let aot = AotSweep::load(&AotSweep::default_dir())?;
            opt.plan_with(&w, &aot)?
        }
        other => anyhow::bail!("--backend: 'native' or 'aot', got '{other}'"),
    };
    let mut out = String::new();
    if args.flag("explain") {
        out.push_str(&format!(
            "Phase 1 [{}]: {} candidates generated, {} feasible \
             analytically.\nPhase 2 [DES]: verified top {} by cost:\n",
            plan.backend,
            plan.n_candidates,
            plan.n_phase1_feasible,
            plan.verified.len()
        ));
        let mut t = Table::new(&["Candidate", "$/yr", "rho s/l",
                                 "DES P99 TTFT", "verdict"]);
        for e in &plan.verified {
            let v = e.verification.as_ref().unwrap();
            t.row(&[
                e.candidate.label(),
                dollars(e.analytic.cost_yr),
                format!("{:.2}/{:.2}", e.analytic.rho_s, e.analytic.rho_l),
                millis(v.p99_ttft_ms),
                if v.passed { "pass".into() } else { "fail".into() },
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str(&plan.summary());
    out.push('\n');
    Ok(out)
}

fn cmd_simulate(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let cat = GpuCatalog::standard();
    let gpu = cat.require(args.get_str("gpu", "H100"))?.clone();
    let n_short = args.get_usize("n-short", 2)?;
    let n_long = args.get_usize("n-long", 4)?;
    let b_short = args.get_f64("b-short", 4096.0)?;
    let max_len = w.cdf.max_len();
    let pools = vec![
        SimPool { gpu: gpu.clone(), n_gpus: n_short, ctx_budget: b_short,
                  batch_cap: None },
        SimPool { gpu, n_gpus: n_long, ctx_budget: max_len, batch_cap: None },
    ];
    let router = match args.get_str("router", "length") {
        "length" => RoutingPolicy::Length { b_short },
        "compress" => RoutingPolicy::CompressAndRoute {
            b_short,
            gamma: args.get_f64("gamma", 1.5)?,
        },
        "random" => RoutingPolicy::Random { n_pools: 2 },
        other => anyhow::bail!("--router: unknown '{other}'"),
    };
    let opts = scenario_opts(args)?;
    let knobs = SimKnobs::from_args(args)?;
    let faults = knobs.load_faults()?;
    if let Some(script) = &faults {
        // Pool indices in the script must exist in this 2-pool layout.
        script
            .validate(pools.len())
            .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
    }
    let retries = knobs.load_retries()?;
    let memory = knobs.load_memory()?;
    if let Some(m) = &memory {
        // Per-pool capacity (and the retry-exclusion rule) must hold
        // for this 2-pool layout before the engine panics on it.
        let cfg = opts.des();
        let probe =
            crate::des::input::SimInput::stream(&pools, &router, &cfg, &[]);
        let probe = match &retries {
            Some(rc) => probe.with_retries(rc),
            None => probe,
        };
        probe
            .with_memory(m)
            .validate()
            .map_err(|e| anyhow::anyhow!("--memory: {e}"))?;
    }
    let engine = scenarios::default_engine(&opts);
    let mut r = engine.simulate_with(
        &w,
        &pools,
        &router,
        &opts.des(),
        faults.as_ref(),
        retries.as_ref(),
        memory.as_ref(),
    );
    let mut t = Table::new(&["Pool", "requests", "util", "wait99", "TTFT99",
                             "E2E99", "max queue"]);
    for (i, p) in r.per_pool.iter_mut().enumerate() {
        // A pool that served nothing has no latency distribution: render
        // "-", not a vacuous 0 ms.
        let served = p.stats.count;
        let lat = move |s: f64| if served == 0 {
            millis(f64::NAN)
        } else {
            millis(s)
        };
        t.row(&[
            if i == 0 { "short".into() } else { "long".into() },
            p.stats.count.to_string(),
            format!("{:.0}%", p.utilization * 100.0),
            lat(p.stats.wait.p99()),
            lat(p.stats.ttft.p99()),
            lat(p.stats.e2e.p99()),
            p.max_queue_depth.to_string(),
        ]);
    }
    let overall_p99 = if r.overall.count == 0 {
        f64::NAN
    } else {
        r.overall.p99_ttft()
    };
    let mut out = format!(
        "{}\noverall P99 TTFT = {} over {} requests ({} compressed, {} \
         unserved)\n",
        t.render(),
        millis(overall_p99),
        r.n_requests,
        r.n_compressed,
        r.n_unserved,
    );
    if let Some(script) = &faults {
        out.push_str(&format!(
            "fault script applied: {} failure(s), {} straggler(s)\n",
            script.failures.len(),
            script.stragglers.len(),
        ));
    }
    if retries.is_some() {
        out.push_str(&format!(
            "retry policy applied: {} attempt(s), amplification \
             {:.2}x, goodput {:.1} rps vs throughput {:.1} rps, {} \
             abandoned, {} shed\n",
            r.n_attempts,
            r.retry_amplification(),
            r.goodput_rps(),
            r.throughput_rps(),
            r.n_abandoned,
            r.n_shed,
        ));
    }
    if memory.is_some() {
        out.push_str(&format!(
            "memory model applied: {} preempted ({} ms stalled), KV \
             peak {:.1}% / mean {:.1}%\n",
            r.n_preempted,
            r.preempt_stall_ms.round(),
            r.kv_peak_util * 100.0,
            r.kv_mean_util * 100.0,
        ));
    }
    if let Some(wt) = crate::report::windows::windowed_table(
        &mut r,
        args.get_f64("slo", 500.0)?,
    ) {
        out.push_str(&wt.render());
        out.push('\n');
    }
    Ok(out)
}

fn cmd_whatif(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let cat = GpuCatalog::standard();
    let gpu = cat.require(args.get_str("gpu", "H100"))?.clone();
    let slo = args.get_f64("slo", 500.0)?;
    let lambdas = args.get_f64_list(
        "lambdas",
        &[25.0, 50.0, 100.0, 150.0, 200.0, 300.0, 400.0],
    )?;
    let sweep = WhatIfSweep::new(cat, slo).for_gpu(&gpu);
    let rows = sweep.sweep(&w, &lambdas);
    let mut t = Table::new(&["λ (req/s)", "config", "GPUs", "Cost/yr",
                             "provision before λ ="]);
    for r in &rows {
        t.row(&[
            format!("{:.0}", r.lambda_rps),
            r.candidate.label(),
            r.candidate.total_gpus().to_string(),
            dollars(r.cost_yr),
            r.headroom_rps.map(|h| format!("{h:.0}")).unwrap_or("-".into()),
        ]);
    }
    Ok(format!("{}\n", t.render()))
}

fn cmd_disagg(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let ttft = args.get_f64("ttft-slo", 500.0)?;
    let tpot = args.get_f64("tpot-slo", 100.0)?;
    let opts = scenario_opts(args)?;
    let o = DisaggFleetOptimizer::new(GpuCatalog::standard(), ttft, tpot);
    let mut t = Table::new(&["Config", "Cost/yr", "TTFT", "TTFT(DES)",
                             "TPOT", "rho P/D", "feasible"]);
    for (cfg, a) in o.sweep(&w) {
        let (des, _, _) = simulate_disagg(&w, &cfg, opts.n_requests, opts.seed);
        t.row(&[
            cfg.label(),
            dollars(a.cost_yr),
            millis(a.ttft99_ms),
            millis(des),
            millis(a.tpot_ms),
            format!("{:.2}/{:.2}", a.rho_prefill, a.rho_decode),
            a.feasible.to_string(),
        ]);
    }
    Ok(format!("{}\n", t.render()))
}

fn cmd_gridflex(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let cat = GpuCatalog::standard();
    let gpu = cat.require(args.get_str("gpu", "H100"))?.clone();
    let cfg = GridFlexConfig {
        n_gpus: args.get_usize("gpus", 40)?,
        slo_ms: args.get_f64("slo", 500.0)?,
        n_requests: args.get_usize("requests", 15_000)?,
        ..Default::default()
    };
    let rows = grid_flex_analysis(&w, &gpu, &cfg);
    let mut t = Table::new(&["Flex", "n_max", "W/GPU", "Fleet kW",
                             "P99 anal.", "P99 DES", "P99 event", "SLO"]);
    for r in &rows {
        t.row(&[
            format!("{:.0}%", r.flex * 100.0),
            r.n_max.to_string(),
            format!("{:.0}", r.w_per_gpu),
            format!("{:.1}", r.fleet_kw),
            millis(r.p99_analytic_ms),
            millis(r.p99_des_ms),
            millis(r.p99_event_ms),
            format!(
                "{}{}",
                if r.steady_ok { "steady" } else { "-" },
                if r.event_ok { "+event" } else { "" }
            ),
        ]);
    }
    Ok(format!("{}\n", t.render()))
}

fn cmd_bench(args: &Args) -> anyhow::Result<String> {
    use crate::report::perf::{render_table, run_bench, run_scale_bench,
                              to_json, BenchEngine, BenchOpts,
                              ScaleBenchOpts};
    let knobs = SimKnobs::from_args(args)?;
    let fast = args.flag("fast");
    let default_requests = if fast { 8_000 } else { 30_000 };
    let opts = BenchOpts {
        n_requests: knobs.requests_or(default_requests),
        seed: knobs.seed_or(42),
        samples: args.get_usize("samples", 3)?.max(1),
        engine: BenchEngine::parse(args.get_str("engine", "both"))?,
    };
    let mut rows = run_bench(&opts);
    let mut scale_note = String::new();
    if args.flag("scale") {
        let defaults = ScaleBenchOpts::default();
        let default_scale = if fast { 2_000_000 } else { defaults.n_requests };
        let scale = ScaleBenchOpts {
            n_requests: args.get_usize("scale-requests", default_scale)?,
            seed: opts.seed,
            n_shards: knobs.shards_or(defaults.n_shards),
            chunk_size: knobs.chunk_size_or(defaults.chunk_size),
            ..defaults
        };
        // The bit-identity prefix check materializes its stream; never
        // verify more than the timed run simulates.
        let scale = ScaleBenchOpts {
            verify_requests: scale.verify_requests.min(scale.n_requests),
            ..scale
        };
        let (row, stats) = run_scale_bench(&scale);
        scale_note = format!(
            "scale run: {} shards, chunk {}, arena peak {} slots \
             ({} chunks)\n",
            scale.n_shards, scale.chunk_size, stats.arena_peak_slots,
            stats.n_chunks,
        );
        rows.push(row);
    }
    let doc = to_json(&opts, &rows);
    let text = doc.to_string_pretty() + "\n";
    if let Some(path) = args.get("out") {
        std::fs::write(path, &text)
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    }
    if args.flag("json") {
        return Ok(text);
    }
    let mut out = render_table(&rows);
    out.push_str(&scale_note);
    if let Some(path) = args.get("out") {
        out.push_str(&format!("snapshot written to {path}\n"));
    }
    Ok(out)
}

fn cmd_fidelity(args: &Args) -> anyhow::Result<String> {
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let n = args.get_usize("requests", 10_000)?;
    Ok(format!("{}\n", fidelity_table(&gpu, n).render()))
}

fn cmd_ablation(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let cat = GpuCatalog::standard();
    let gpu = cat.require(args.get_str("gpu", "H100"))?.clone();
    let n = args.get_usize("requests", 10_000)?;
    Ok(format!(
        "{}\n",
        crate::report::ablation::table(&w, &gpu, &[8, 10, 14, 20], n)
            .render()
    ))
}

fn cmd_sensitivity(args: &Args) -> anyhow::Result<String> {
    let lam = args.get_f64("lambda", 50.0)?;
    let slo = args.get_f64("slo", 1000.0)?;
    let seed = args.get_usize("seed", 3)? as u64;
    Ok(format!("{}\n",
               crate::report::sensitivity::table(lam, slo, seed).render()))
}

fn cmd_substream(args: &Args) -> anyhow::Result<String> {
    let w = workload_from(args)?;
    let cat = GpuCatalog::standard();
    let gpu = cat.require(args.get_str("gpu", "H100"))?.clone();
    let b = args.get_f64("b-short", 3072.0)?;
    let opts = scenario_opts(args)?;
    let c = crate::report::substream::substream_check(
        &w, &gpu, args.get_usize("n-short", 6)?,
        args.get_usize("n-long", 3)?, b, opts.n_requests, 0.9, opts.seed);
    let mut t = Table::new(&["Quantity", "short pool", "long pool"]);
    t.row(&["Analytic P99 TTFT (Poisson-split assumption)".into(),
            millis(c.analytic_short_ms), millis(c.analytic_long_ms)]);
    t.row(&["DES P99 TTFT (i.i.d. lengths)".into(),
            millis(c.des_short_ms), millis(c.des_long_ms)]);
    t.row(&["DES P99 TTFT (length-correlated bursts)".into(),
            millis(c.bursty_short_ms), millis(c.bursty_long_ms)]);
    Ok(format!(
        "{}\nlong-pool inter-arrival SCV under bursts: {:.2} (1 = Poisson)\n\
         approximation {} at 50% tolerance\n",
        t.render(), c.long_gap_scv,
        if c.holds(0.5) { "HOLDS" } else { "BREAKS" }
    ))
}

fn cmd_multimodel(args: &Args) -> anyhow::Result<String> {
    let opts = scenario_opts(args)?;
    Ok(crate::scenarios::multi_model::run(&opts).render())
}

fn cmd_puzzle(args: &Args) -> anyhow::Result<String> {
    // Alias for `run --scenario puzzleN`, kept for compatibility; both
    // dispatch through the scenario registry.
    let n: usize = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: fleet-sim puzzle <1..8>"))?
        .parse()?;
    let opts = scenario_opts(args)?;
    Ok(scenarios::run(n, &opts)?.render())
}

fn cmd_reproduce_all(args: &Args) -> anyhow::Result<String> {
    let opts = scenario_opts(args)?;
    let mut out = String::new();
    for report in scenarios::run_all(&opts) {
        out.push_str(&report.render());
        out.push('\n');
    }
    Ok(out)
}

fn cmd_profiles() -> anyhow::Result<String> {
    let cat = GpuCatalog::standard();
    let mut t = Table::new(&["GPU", "W ms", "H ms/slot", "kv blocks",
                             "chunk", "max_num_seqs", "VRAM", "$/hr", "$/yr",
                             "P idle", "P nom"]);
    for g in cat.profiles() {
        t.row(&[
            g.name.clone(),
            format!("{}", g.w_ms),
            format!("{}", g.h_ms_per_slot),
            format!("{}", g.kv_blocks),
            format!("{}", g.chunk),
            format!("{}", g.max_num_seqs),
            format!("{} GB", g.vram_gb),
            format!("${:.2}", g.cost_per_hr),
            dollars(g.cost_per_year()),
            format!("{} W", g.p_idle_w),
            format!("{} W", g.p_nom_w),
        ]);
    }
    let mut r = Table::new(&["node_avail scenario", "A"]);
    r.row(&["soft failure (driver reset, ~4h MTTR)".into(),
            format!("{:.4}", NodeAvail::soft_failure().a)]);
    r.row(&["hard failure (GPU/NVLink swap, ~48h MTTR)".into(),
            format!("{:.4}", NodeAvail::hard_failure().a)]);
    r.row(&["5% overprovisioning rule".into(),
            format!("{:.4}", NodeAvail::five_percent_rule().a)]);
    Ok(format!("{}\n{}\n", t.render(), r.render()))
}

fn cmd_selftest() -> anyhow::Result<String> {
    let dir = AotSweep::default_dir();
    let aot = AotSweep::load(&dir)?;
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let cands = crate::optimizer::candidates::generate(
        &w,
        &GpuCatalog::standard(),
        &crate::optimizer::candidates::GenOptions::default(),
    );
    let native = NativeSweep.eval(&w, &cands, 500.0)?;
    let aot_res = aot.eval(&w, &cands, 500.0)?;
    let agree = native
        .iter()
        .zip(&aot_res)
        .filter(|(n, a)| n.feasible == a.feasible)
        .count();
    anyhow::ensure!(
        agree * 100 >= cands.len() * 99,
        "feasibility agreement {agree}/{} below 99%",
        cands.len()
    );
    Ok(format!(
        "runtime selftest OK: platform={}, artifact={}, {} candidates, \
         {}/{} feasibility agreement\n",
        aot.platform(),
        aot.artifact_path.display(),
        cands.len(),
        agree,
        cands.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cmd(parts: &[&str]) -> anyhow::Result<String> {
        let argv: Vec<String> = parts.iter().map(|s| s.to_string()).collect();
        let args = Args::parse(
            &argv,
            &["fast", "mixed", "explain", "json", "scale"],
        )
        .unwrap();
        run(&args)
    }

    #[test]
    fn help_and_unknown() {
        assert!(run_cmd(&["help"]).unwrap().contains("USAGE"));
        assert!(run_cmd(&["frobnicate"]).is_err());
    }

    #[test]
    fn scenarios_lists_registry() {
        let out = run_cmd(&["scenarios"]).unwrap();
        for key in ["puzzle1", "split-threshold", "multimodel", "gridflex",
                    "diurnal", "size-to-peak", "n_plus_k", "n-plus-k",
                    "retry_storm", "retry-storm", "kv_stability",
                    "kv-stability"] {
            assert!(out.contains(key), "{out}");
        }
    }

    #[test]
    fn run_requires_and_validates_scenario() {
        assert!(run_cmd(&["run"]).is_err());
        let err = run_cmd(&["run", "--scenario", "nope"]).unwrap_err();
        assert!(format!("{err}").contains("registered"), "{err}");
    }

    #[test]
    fn run_by_name_matches_puzzle_alias() {
        // `run --scenario puzzle5` and the legacy `puzzle 5` path must
        // produce the same table (same registry entry, same engine).
        let a = run_cmd(&["run", "--scenario", "puzzle5", "--fast",
                          "--requests", "2000"]).unwrap();
        let b = run_cmd(&["puzzle", "5", "--fast", "--requests", "2000"])
            .unwrap();
        assert_eq!(a, b);
        let by_name = run_cmd(&["run", "--scenario", "routers", "--fast",
                                "--requests", "2000"]).unwrap();
        assert_eq!(a, by_name);
    }

    #[test]
    fn profiles_lists_catalog() {
        let out = run_cmd(&["profiles"]).unwrap();
        for s in ["A10G", "A100", "H100", "0.987"] {
            assert!(out.contains(s), "{out}");
        }
    }

    #[test]
    fn simulate_produces_table() {
        let out = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests", "2000",
        ])
        .unwrap();
        assert!(out.contains("overall P99 TTFT"), "{out}");
        assert!(!out.contains("Windowed SLO"), "{out}");
    }

    #[test]
    fn simulate_with_window_emits_windowed_table() {
        let out = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests", "2000",
            "--window", "10000", "--slo", "500",
        ])
        .unwrap();
        assert!(out.contains("Windowed SLO evaluation"), "{out}");
        assert!(out.contains("attainment"), "{out}");
        // Full argument set so the error can only come from the window
        // validation itself, not an earlier missing-option failure.
        let err = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests", "500",
            "--window", "-5",
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("--window"), "{err}");
    }

    #[test]
    fn simulate_applies_and_validates_fault_scripts() {
        let dir = std::env::temp_dir().join("fleet_sim_cli_faults");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("outage.toml");
        std::fs::write(
            &good,
            "# one failure + one straggler\n\
             [[failure]]\n\
             pool = 1\n\
             n_gpus = 1\n\
             start_ms = 2000\n\
             recover_ms = 8000\n\
             warm_ms = 1000\n\
             warm_factor = 2.0\n\
             \n\
             [[straggler]]\n\
             pool = 0\n\
             n_gpus = 1\n\
             start_ms = 0\n\
             end_ms = 5000\n\
             factor = 1.5\n",
        )
        .unwrap();
        let out = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "2000", "--faults", good.to_str().unwrap(),
        ])
        .unwrap();
        assert!(
            out.contains("fault script applied: 1 failure(s), 1 \
                          straggler(s)"),
            "{out}"
        );

        // A pool index beyond the 2-pool layout is rejected up front.
        let bad = dir.join("bad_pool.toml");
        std::fs::write(
            &bad,
            "[[failure]]\npool = 7\nn_gpus = 1\nstart_ms = 0\n\
             recover_ms = 1000\n",
        )
        .unwrap();
        let err = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--faults", bad.to_str().unwrap(),
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");

        // A missing script file is an error, not a silent no-fault run.
        assert!(run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--faults", "/no/such/file.toml",
        ])
        .is_err());
    }

    #[test]
    fn simulate_applies_and_validates_retry_configs() {
        let dir = std::env::temp_dir().join("fleet_sim_cli_retries");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("clients.toml");
        std::fs::write(
            &good,
            "# lenient closed loop\n\
             [retry]\n\
             max_attempts = 3\n\
             timeout_ms = 60000\n\
             backoff_base_ms = 250\n\
             backoff_cap_ms = 1000\n",
        )
        .unwrap();
        let out = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "2000", "--retries", good.to_str().unwrap(),
        ])
        .unwrap();
        assert!(out.contains("retry policy applied"), "{out}");
        assert!(out.contains("amplification"), "{out}");

        // An invalid config is rejected up front, naming the flag.
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "[retry]\nmax_attempts = 2\n").unwrap();
        let err = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--retries", bad.to_str().unwrap(),
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("--retries"), "{err}");

        // A missing config file is an error, not a silent open-loop run.
        assert!(run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--retries", "/no/such/clients.toml",
        ])
        .is_err());
    }

    #[test]
    fn simulate_applies_and_validates_memory_configs() {
        let dir = std::env::temp_dir().join("fleet_sim_cli_memory");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("hbm.toml");
        std::fs::write(
            &good,
            "# roomy KV budget\n\
             [memory]\n\
             weights_gb = 60\n\
             bytes_per_token = 5e5\n\
             policy = \"evict-recompute\"\n",
        )
        .unwrap();
        let out = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "2000", "--memory", good.to_str().unwrap(),
        ])
        .unwrap();
        assert!(out.contains("memory model applied"), "{out}");
        assert!(out.contains("KV peak"), "{out}");

        // A malformed config is rejected up front, naming the flag.
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "[memory]\nweights_gb = 60\n").unwrap();
        let err = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--memory", bad.to_str().unwrap(),
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("--memory"), "{err}");

        // A spec leaving less than one max-context request of capacity
        // is rejected against the actual layout, not at parse time.
        let tiny = dir.join("tiny.toml");
        std::fs::write(
            &tiny,
            "[memory]\n\
             weights_gb = 79.9999\n\
             bytes_per_token = 1e6\n\
             policy = \"none\"\n",
        )
        .unwrap();
        let err = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--memory", tiny.to_str().unwrap(),
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("--memory"), "{err}");

        // Memory + retries is rejected as a combination, up front.
        let clients = dir.join("clients.toml");
        std::fs::write(
            &clients,
            "[retry]\n\
             max_attempts = 3\n\
             timeout_ms = 60000\n\
             backoff_base_ms = 250\n\
             backoff_cap_ms = 1000\n",
        )
        .unwrap();
        let err = run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--memory", good.to_str().unwrap(), "--retries",
            clients.to_str().unwrap(),
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("retry"), "{err}");

        // A missing config file is an error, not a silent run.
        assert!(run_cmd(&[
            "simulate", "--trace", "azure", "--lambda", "50", "--gpu",
            "H100", "--n-short", "2", "--n-long", "2", "--requests",
            "500", "--memory", "/no/such/hbm.toml",
        ])
        .is_err());
    }

    #[test]
    fn plan_native_fast() {
        let out = run_cmd(&[
            "plan", "--trace", "azure", "--lambda", "50", "--requests",
            "2000", "--explain",
        ])
        .unwrap();
        assert!(out.contains("Phase 1"), "{out}");
        assert!(out.contains("$"), "{out}");
    }

    #[test]
    fn bad_router_and_gpu_rejected() {
        assert!(run_cmd(&["simulate", "--router", "psychic"]).is_err());
        assert!(run_cmd(&["simulate", "--gpu", "B200"]).is_err());
    }

    #[test]
    fn bench_reports_speedup_table_and_json() {
        let out = run_cmd(&["bench", "--requests", "1200", "--samples", "1"])
            .unwrap();
        assert!(out.contains("speedup"), "{out}");
        assert!(out.contains("azure_two_pool_length"), "{out}");
        let js = run_cmd(&["bench", "--requests", "800", "--samples", "1",
                           "--engine", "production", "--json"]).unwrap();
        assert!(js.contains("\"schema\""), "{js}");
        assert!(js.contains("events_per_sec"), "{js}");
        assert!(run_cmd(&["bench", "--engine", "warp"]).is_err());
    }

    #[test]
    fn bench_scale_adds_sharded_row() {
        let out = run_cmd(&[
            "bench", "--requests", "800", "--samples", "1", "--engine",
            "production", "--scale", "--scale-requests", "6000",
            "--shards", "2", "--chunk-size", "1024",
        ])
        .unwrap();
        assert!(out.contains("lmsys_1e8"), "{out}");
        assert!(out.contains("arena peak"), "{out}");
        let js = run_cmd(&[
            "bench", "--requests", "800", "--samples", "1", "--engine",
            "production", "--scale", "--scale-requests", "6000",
            "--shards", "2", "--json",
        ])
        .unwrap();
        assert!(js.contains("\"lmsys_1e8\""), "{js}");
    }

    #[test]
    fn extension_commands_produce_tables() {
        let out = run_cmd(&["multimodel", "--requests", "2000"]).unwrap();
        assert!(out.contains("ModelRouter"), "{out}");
        let out = run_cmd(&[
            "substream", "--trace", "azure", "--lambda", "60", "--requests",
            "3000",
        ])
        .unwrap();
        assert!(out.contains("approximation"), "{out}");
        let out = run_cmd(&["ablation", "--requests", "2000"]).unwrap();
        assert!(out.contains("n_max model"), "{out}");
    }
}
