"""AOT compile path: lower the L2 sweep to HLO text for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  python -m compile.aot --out ../artifacts/sweep.hlo.txt
Also writes sweep.meta.json next to it (static shapes + field order) so the
rust side can validate its packing against the artifact.
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from .model import (CANDIDATE_FIELDS, K_BINS, N_CAND, OUTPUT_COLUMNS,
                    lower_sweep)
from .kernels.ref import C_MAX


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def build(out_path: str, n: int = N_CAND, k: int = K_BINS) -> dict:
    lowered = lower_sweep(n=n, k=k, interpret=True)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    meta = {
        "n_cand": n,
        "k_bins": k,
        "c_max": C_MAX,
        "candidate_fields": list(CANDIDATE_FIELDS),
        "output_columns": list(OUTPUT_COLUMNS),
        "hlo_bytes": len(text),
    }
    meta_path = os.path.splitext(out_path)[0]
    meta_path = meta_path[:-4] if meta_path.endswith(".hlo") else meta_path
    meta_path += ".meta.json"
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/sweep.hlo.txt")
    ap.add_argument("--n-cand", type=int, default=N_CAND)
    ap.add_argument("--k-bins", type=int, default=K_BINS)
    args = ap.parse_args()
    meta = build(args.out, n=args.n_cand, k=args.k_bins)
    print(f"wrote {meta['hlo_bytes']} chars to {args.out} "
          f"(N={meta['n_cand']}, K={meta['k_bins']})")


if __name__ == "__main__":
    main()
