"""L1 Pallas kernel: batched Erlang-C waiting probability (paper Eq. 1).

The Phase-1 analytical sweep evaluates Erlang-C for every candidate fleet
configuration. This kernel vectorizes the numerically stable Erlang-B
recurrence across a tile of candidates (lane dimension) and runs the
k = 1..C_MAX recurrence as the sequential dimension:

    B_0 = 1,   B_k = a B_{k-1} / (k + a B_{k-1}),   a = c * rho
    C(c, rho) = B_c / (1 - rho (1 - B_c))

Each lane freezes its output once k reaches its own server count c, so one
fixed-length loop serves the whole batch.

TPU mapping (see DESIGN.md §Hardware-Adaptation): candidates live in the
128-wide lane dimension of the VPU; the recurrence is the sequential axis.
A tile of TILE=256 f32 candidates uses < 8 KB of VMEM — the kernel is
compute-bound on the VPU, which is the right place for it (no MXU work
here; the moment reductions in moments.py are the MXU-shaped half).

On CPU we lower with interpret=True (Mosaic custom-calls cannot execute on
the CPU PJRT plugin) so the kernel folds into plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import C_MAX

TILE = 256


def _erlang_kernel(rho_ref, c_ref, out_ref, *, c_max: int):
    rho = rho_ref[...]
    c = c_ref[...]
    a = rho * c

    def body(k, carry):
        b, out = carry
        kf = k.astype(jnp.float32)
        b = a * b / (kf + a * b)
        out = jnp.where(kf == c, b, out)
        return b, out

    b0 = jnp.ones_like(a)
    _, b_at_c = jax.lax.fori_loop(1, c_max + 1, body, (b0, b0))

    denom = 1.0 - rho * (1.0 - b_at_c)
    cc = jnp.where(denom > 0, b_at_c / jnp.maximum(denom, 1e-30), 1.0)
    cc = jnp.where(rho < 1.0, cc, 1.0)
    out_ref[...] = jnp.clip(cc, 0.0, 1.0)


@functools.partial(jax.jit, static_argnames=("c_max", "interpret"))
def erlang_c(rho, c, c_max: int = C_MAX, interpret: bool = True):
    """Batched Erlang-C C(c, rho) over 1-D arrays of candidates.

    rho: [N] per-server utilization; c: [N] server counts (float-typed
    integers, clamped to c_max by the caller). Unstable lanes (rho >= 1)
    return 1.0. N must be a multiple of TILE (the L2 model pads).
    """
    rho = jnp.asarray(rho, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    (n,) = rho.shape
    assert n % TILE == 0, f"N={n} must be a multiple of TILE={TILE}"
    grid = (n // TILE,)
    return pl.pallas_call(
        functools.partial(_erlang_kernel, c_max=c_max),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        interpret=interpret,
    )(rho, c)
