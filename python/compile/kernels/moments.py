"""L1 Pallas kernel: per-candidate pool iteration moments + P99 lengths.

Phase-1 step 2 of the paper (§3.1): for each candidate split threshold
B_short, integrate the per-request slot-hold iteration count (Eq. 4
numerator) over the workload CDF restricted to each pool's length range,
producing

    alpha_s            traffic fraction routed short
    E[I], E[I^2]       conditional iteration-count moments per pool
    p99_len_{s,l}      conditional 99th-pct token budget per pool
                       (feeds the T_prefill term of Eq. 5)

Iteration counts (not service times) are the right kernel output: the L2
model converts them to service times at the pool's *equilibrium*
concurrency (Little's law on the linear t_iter), which depends on lambda
and the pool's own moments — a scalar epilogue, not a per-bin integral.

The kernel tiles candidates (TILE per block) and keeps the full K-bin
histogram resident per block; the inner products are (TILE x K) masked
weighted reductions.

TPU mapping (DESIGN.md §Hardware-Adaptation): the (TILE x K) working set at
TILE=128, K=256 is 128 KB of f32 — comfortably VMEM-resident; the weighted
reductions are contractions over K that the MXU executes as masked matmuls
(weights-as-diagonal trick), while the ceil/where preludes run on the VPU.
On CPU we lower with interpret=True so everything folds into plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128
BIG = 3.0e7  # sentinel larger than any token budget (300K max in traces)


def _moments_kernel(hist_p_ref, hist_len_ref, b_ref, frac_ref,
                    chunk_s_ref, chunk_l_ref,
                    alpha_ref, i1_s_ref, i2_s_ref, i1_l_ref, i2_l_ref,
                    p99s_ref, p99l_ref):
    hist_p = hist_p_ref[...][None, :]      # [1,K]
    hist_len = hist_len_ref[...][None, :]  # [1,K]
    b = b_ref[...][:, None]                # [T,1]
    frac = frac_ref[...][:, None]          # [T,1] input fraction
    chunk_s = chunk_s_ref[...][:, None]
    chunk_l = chunk_l_ref[...][:, None]

    mask_s = (hist_len <= b).astype(jnp.float32)   # [T,K]
    mask_l = 1.0 - mask_s

    l_in = jnp.ceil(hist_len * frac)
    l_out = jnp.maximum(hist_len - l_in, 1.0)
    iters_s = jnp.ceil(l_in / chunk_s) + l_out
    iters_l = jnp.ceil(l_in / chunk_l) + l_out

    eps = 1e-12
    w_s = hist_p * mask_s
    w_l = hist_p * mask_l
    alpha_s = jnp.sum(w_s, axis=1)
    alpha_l = jnp.sum(w_l, axis=1)

    i1_s = jnp.sum(w_s * iters_s, axis=1) / jnp.maximum(alpha_s, eps)
    i2_s = jnp.sum(w_s * iters_s * iters_s, axis=1) / jnp.maximum(alpha_s, eps)
    i1_l = jnp.sum(w_l * iters_l, axis=1) / jnp.maximum(alpha_l, eps)
    i2_l = jnp.sum(w_l * iters_l * iters_l, axis=1) / jnp.maximum(alpha_l, eps)

    # Conditional P99 token budget per pool: first bin whose pool-local
    # cumulative probability reaches 0.99 * alpha.
    cum_s = jnp.cumsum(w_s, axis=1)
    cum_l = jnp.cumsum(w_l, axis=1)
    tgt_s = (0.99 * alpha_s)[:, None]
    tgt_l = (0.99 * alpha_l)[:, None]
    cand_s = jnp.where((cum_s >= tgt_s) & (mask_s > 0), hist_len, BIG)
    cand_l = jnp.where((cum_l >= tgt_l) & (mask_l > 0), hist_len, BIG)
    p99_s = jnp.min(cand_s, axis=1)
    p99_l = jnp.min(cand_l, axis=1)
    # Empty pools report 0 so downstream TTFT terms vanish.
    p99_s = jnp.where(alpha_s > eps, p99_s, 0.0)
    p99_l = jnp.where(alpha_l > eps, p99_l, 0.0)

    alpha_ref[...] = alpha_s
    i1_s_ref[...] = i1_s
    i2_s_ref[...] = i2_s
    i1_l_ref[...] = i1_l
    i2_l_ref[...] = i2_l
    p99s_ref[...] = p99_s
    p99l_ref[...] = p99_l


@functools.partial(jax.jit, static_argnames=("interpret",))
def pool_moments(hist_p, hist_len, b_short, input_frac, chunk_s, chunk_l,
                 interpret: bool = True):
    """Batched pool iteration moments. Candidate args are [N] f32
    (N % TILE == 0); hist_p/hist_len are [K] f32. Returns a tuple of seven
    [N] arrays: (alpha_s, i1_s, i2_s, i1_l, i2_l, p99_len_s, p99_len_l).
    """
    hist_p = jnp.asarray(hist_p, jnp.float32)
    hist_len = jnp.asarray(hist_len, jnp.float32)
    args = [jnp.asarray(a, jnp.float32) for a in
            (b_short, input_frac, chunk_s, chunk_l)]
    (n,) = args[0].shape
    (k,) = hist_p.shape
    assert n % TILE == 0, f"N={n} must be a multiple of TILE={TILE}"
    grid = (n // TILE,)
    hist_spec = pl.BlockSpec((k,), lambda i: (0,))
    cand_spec = pl.BlockSpec((TILE,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _moments_kernel,
        out_shape=(out,) * 7,
        grid=grid,
        in_specs=[hist_spec, hist_spec] + [cand_spec] * 4,
        out_specs=(cand_spec,) * 7,
        interpret=interpret,
    )(hist_p, hist_len, *args)
