"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `ref_*` counterpart to float32 tolerance under pytest +
hypothesis sweeps (python/tests/).

The two kernels implement the numeric hot loop of the Phase-1 analytical
sweep (paper §3.1):

* ``ref_erlang_c`` — Erlang-C waiting probability C(c, rho) (paper Eq. 1)
  for a batch of candidate pools, computed with the numerically stable
  Erlang-B recurrence:

      B_0 = 1,   B_k = a * B_{k-1} / (k + a * B_{k-1}),   a = c * rho
      C(c, rho) = B_c / (1 - rho * (1 - B_c))

  The recurrence runs a fixed C_MAX iterations with a mask that freezes the
  value once k == c, so the whole batch shares one loop (SIMD/VPU friendly —
  this is what the Pallas kernel vectorizes over lanes).

* ``ref_pool_moments`` — per-candidate service-time moments of the two pools
  induced by a split threshold B_short over a discretized token-length
  histogram (paper §3.1 step 2): traffic fraction alpha_s, E[S] and E[S^2]
  restricted to each pool, where the per-request slot-hold time follows
  Eq. 4 of the paper.
"""

from __future__ import annotations

import jax.numpy as jnp

# Maximum server count supported by the fixed-length Erlang-B recurrence.
# Fleet sizes above this are clamped (the planner never sweeps beyond it).
C_MAX = 512


def ref_erlang_b(a, c, c_max: int = C_MAX):
    """Erlang-B blocking probability B(c, a) in log space.

    Deliberately a *different algorithm* from the Pallas kernel (which uses
    the Erlang-B recurrence): here we evaluate the closed form

        B(c, a) = (a^c / c!) / sum_{k=0..c} a^k / k!

    via log-space terms log t_k = k log a - lgamma(k+1) and a masked
    logsumexp over k = 0..c_max, so kernel-vs-ref agreement genuinely
    cross-checks two independent derivations.

    a: offered load (= c * rho); c: server counts (float-typed integers).
    """
    a = jnp.asarray(a, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    shape = jnp.broadcast_shapes(a.shape, c.shape)
    a = jnp.broadcast_to(a, shape).reshape(-1)[:, None]       # [N,1]
    c_col = jnp.broadcast_to(c, shape).reshape(-1)[:, None]   # [N,1]
    k = jnp.arange(c_max + 1, dtype=jnp.float32)[None, :]     # [1,K]
    log_a = jnp.log(jnp.maximum(a, 1e-30))
    log_t = k * log_a - _gammaln(k + 1.0)                     # [N,K]
    log_t = jnp.where(k <= c_col, log_t, -jnp.inf)
    m = jnp.max(log_t, axis=1, keepdims=True)
    log_den = m[:, 0] + jnp.log(jnp.sum(jnp.exp(log_t - m), axis=1))
    log_num = (c_col[:, 0]) * log_a[:, 0] - _gammaln(c_col[:, 0] + 1.0)
    b = jnp.exp(log_num - log_den)
    return jnp.asarray(b.reshape(shape), jnp.float32)


def _gammaln(x):
    from jax.scipy.special import gammaln
    return gammaln(x)


def ref_erlang_c(rho, c, c_max: int = C_MAX):
    """Erlang-C waiting probability C(c, rho) (paper Eq. 1).

    rho: per-server utilization; c: server counts. Returns 1.0 for
    unstable lanes (rho >= 1) — the planner treats that as an automatic
    SLO failure.
    """
    rho = jnp.asarray(rho, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    a = rho * c
    b = ref_erlang_b(a, c, c_max)
    denom = 1.0 - rho * (1.0 - b)
    cc = jnp.where(denom > 0, b / jnp.maximum(denom, 1e-30), 1.0)
    cc = jnp.where(rho < 1.0, cc, 1.0)
    return jnp.clip(cc, 0.0, 1.0)


def ref_slot_hold_iters(lengths, input_frac, chunk):
    """Iterations a request of total token budget L occupies a KV slot.

    iters(L) = ceil(L_in / C_chunk) + L_out,   L_in = input_frac * L,
    L_out = L - L_in (at least 1)  — paper Eq. 4 numerator.
    """
    l_in = jnp.ceil(lengths * input_frac)
    l_out = jnp.maximum(lengths - l_in, 1.0)
    return jnp.ceil(l_in / chunk) + l_out


def ref_pool_moments(hist_p, hist_len, b_short, input_frac, chunk_s, chunk_l):
    """Iteration moments for both pools of each candidate (§3.1 step 2).

    Args (all jnp arrays):
      hist_p:   [K] bin probabilities (sum to 1)
      hist_len: [K] bin centers — total token budget per request
      b_short:  [N] candidate split thresholds
      input_frac: scalar or [N] — fraction of the budget that is prompt
      chunk_s/chunk_l: [N] prefill chunk size of the GPU type in each pool

    Returns dict of [N] arrays: alpha_s, i1_s, i2_s, i1_l, i2_l (mean and
    second moment of the slot-hold iteration count, Eq. 4 numerator,
    conditioned on the pool) plus p99_len_{s,l}.
    """
    hist_p = jnp.asarray(hist_p, jnp.float32)[None, :]      # [1,K]
    hist_len = jnp.asarray(hist_len, jnp.float32)[None, :]  # [1,K]
    b = jnp.asarray(b_short, jnp.float32)[:, None]          # [N,1]
    # input_frac may be a scalar or a per-candidate [N] array.
    frac = jnp.asarray(input_frac, jnp.float32).reshape(-1)[:, None]

    mask_s = (hist_len <= b).astype(jnp.float32)            # [N,K]
    mask_l = 1.0 - mask_s

    iters_s = ref_slot_hold_iters(hist_len, frac, chunk_s[:, None])
    iters_l = ref_slot_hold_iters(hist_len, frac, chunk_l[:, None])

    alpha_s = jnp.sum(hist_p * mask_s, axis=1)
    alpha_l = jnp.sum(hist_p * mask_l, axis=1)  # exact-zero for empty pools
    eps = 1e-12

    def cond_moments(s, mask, alpha):
        w = hist_p * mask
        m1 = jnp.sum(w * s, axis=1) / jnp.maximum(alpha, eps)
        m2 = jnp.sum(w * s * s, axis=1) / jnp.maximum(alpha, eps)
        return m1, m2

    es_s, es2_s = cond_moments(iters_s, mask_s, alpha_s)
    es_l, es2_l = cond_moments(iters_l, mask_l, alpha_l)

    # Conditional P99 token budget per pool (independent formulation from
    # the kernel: searchsorted over the pool-local CDF).
    big = 3.0e7
    cum_s = jnp.cumsum(hist_p * mask_s, axis=1)
    cum_l = jnp.cumsum(hist_p * mask_l, axis=1)
    tgt_s = (0.99 * alpha_s)[:, None]
    tgt_l = (0.99 * alpha_l)[:, None]
    p99_s = jnp.min(jnp.where((cum_s >= tgt_s) & (mask_s > 0), hist_len, big),
                    axis=1)
    p99_l = jnp.min(jnp.where((cum_l >= tgt_l) & (mask_l > 0), hist_len, big),
                    axis=1)
    p99_s = jnp.where(alpha_s > eps, p99_s, 0.0)
    p99_l = jnp.where(alpha_l > eps, p99_l, 0.0)
    return {
        "alpha_s": alpha_s,
        "i1_s": es_s, "i2_s": es2_s,
        "i1_l": es_l, "i2_l": es2_l,
        "p99_len_s": p99_s, "p99_len_l": p99_l,
    }
