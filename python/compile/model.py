"""L2: the Phase-1 analytical sweep as one JAX computation (paper §3.1).

``sweep_eval`` evaluates every candidate fleet configuration in a single
batched pass:

  1. pool iteration moments from the workload histogram (L1 moments kernel),
  2. equilibrium concurrency per pool (Little's law on the linear t_iter —
     the "recalibrated service rate" of paper §4.8),
  3. Erlang-C waiting probability per pool (L1 erlang kernel),
  4. Kimura two-moment P99 wait (paper Eq. 2),
  5. TTFT decomposition (paper Eq. 5) with the conditional-P99 prefill term,
  6. utilization cap rho <= RHO_MAX, cost, and feasibility.

This function is AOT-lowered once by aot.py to artifacts/sweep.hlo.txt and
executed from the rust coordinator (rust/src/runtime/) via PJRT — python is
never on the planning path. It is numerically mirrored by the pure-rust
evaluator in rust/src/optimizer/analytic.rs; rust/tests/runtime_parity.rs
asserts the two agree.

Candidate encoding (all f32, shape [N]):
  b_short     split threshold in tokens (>= max token -> single pool)
  n_s, n_l    GPU counts per pool (n_l == 0 -> homogeneous candidate)
  chunk_s/l   prefill chunk size of the pool's GPU type
  nmax_s/l    effective KV-slot count (min(n_max(ctx), max_num_seqs))
  w_s/l       GPU baseline compute W (ms)
  h_s/l       GPU per-slot cost H (ms/slot)
  cost_s/l    $/yr per GPU of the pool's type
  input_frac  prompt fraction of the token budget
  lam         total arrival rate in req/ms
  slo         P99 TTFT SLO in ms

Output (f32 [N, 8]) columns:
  0 rho_s   1 rho_l   2 ttft99_s   3 ttft99_l
  4 w99_s   5 w99_l   6 cost_yr    7 feasible (1.0 / 0.0)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernels.erlang import erlang_c
from .kernels.moments import pool_moments
from .kernels.ref import C_MAX

RHO_MAX = 0.85       # queueing-stability utilization cap (paper §3.1)
LN_100 = math.log(100.0)

# Static sweep shape baked into the AOT artifact. The rust side pads.
N_CAND = 4096
K_BINS = 256

CANDIDATE_FIELDS = (
    "b_short", "n_s", "n_l", "chunk_s", "chunk_l", "nmax_s", "nmax_l",
    "w_s", "h_s", "w_l", "h_l", "cost_s", "cost_l", "input_frac", "lam",
    "slo",
)
OUTPUT_COLUMNS = (
    "rho_s", "rho_l", "ttft99_s", "ttft99_l", "w99_s", "w99_l",
    "cost_yr", "feasible",
)


def equilibrium_batch(w, h, n_eff, tokens_per_ms_per_gpu):
    """Little's-law equilibrium concurrency under t_iter(n) = W + H n.

    n̄ = a W / (1 - a H) clamped to [1, n_eff]; saturates at n_eff when the
    demanded token rate a reaches the 1/H ceiling. Mirrors
    rust queueing::mgc::equilibrium_batch.
    """
    a = tokens_per_ms_per_gpu
    sat = a * h >= 1.0
    denom = jnp.maximum(1.0 - a * h, 1e-9)
    n_bar = jnp.clip(a * w / denom, 1.0, n_eff)
    return jnp.where(sat, n_eff, n_bar)


def kimura_w99(erl_c, c, es, es2_over_es1_sq, rho):
    """Kimura two-moment M/G/c P99 queue wait (paper Eq. 2), in ms.

    W99 = C(c, rho) / (c mu (1 - rho)) * (1 + Cs^2)/2 * ln(100),
    with mu = 1 / E[S]. `es2_over_es1_sq` is E[S^2]/E[S]^2 (= 1 + Cs^2).
    Unstable lanes (rho >= 1) return +inf.
    """
    eps = 1e-9
    cs2 = jnp.maximum(es2_over_es1_sq - 1.0, 0.0)
    c_mu = c / jnp.maximum(es, eps)
    w = erl_c / jnp.maximum(c_mu * (1.0 - rho), eps)
    w99 = w * (1.0 + cs2) * 0.5 * LN_100
    return jnp.where(rho < 1.0, w99, jnp.inf)


def _pool_eval(alpha, i1, i2, p99_len, n_eff, w, h, chunk, n_gpus,
               input_frac, lam, empty, interpret):
    """Evaluate one pool's rho / W99 / TTFT given its iteration moments."""
    eps = 1e-9
    c = jnp.clip(n_gpus, 1.0, float(C_MAX))
    lam_pool = lam * alpha
    a = lam_pool * i1 / c                      # demanded tokens/ms/GPU
    n_bar = equilibrium_batch(w, h, n_eff, a)
    t_bar = w + h * n_bar
    es = i1 * t_bar / jnp.maximum(n_eff, 1.0)
    rho = jnp.where(empty, 0.0, lam_pool * es / c)
    ratio = i2 / jnp.maximum(i1 * i1, eps)     # E[S²]/E[S]² (t̄ cancels)
    erl = erlang_c(rho, c, interpret=interpret)
    w99 = jnp.where(empty, 0.0, kimura_w99(erl, c, es, ratio, rho))
    l_in99 = jnp.ceil(p99_len * input_frac)
    prefill = jnp.ceil(l_in99 / chunk) * t_bar
    ttft = jnp.where(empty, 0.0, w99 + prefill + t_bar)
    return rho, w99, ttft


def sweep_eval(hist_p, hist_len, b_short, n_s, n_l, chunk_s, chunk_l,
               nmax_s, nmax_l, w_s, h_s, w_l, h_l, cost_s, cost_l,
               input_frac, lam, slo, interpret: bool = True):
    """Evaluate [N] candidates against a [K]-bin workload histogram."""
    (alpha_s, i1_s, i2_s, i1_l, i2_l, p99_s, p99_l) = pool_moments(
        hist_p, hist_len, b_short, input_frac, chunk_s, chunk_l,
        interpret=interpret)

    alpha_l = 1.0 - alpha_s
    empty_s = alpha_s <= 1e-9
    empty_l = (alpha_l <= 1e-9) | (n_l < 0.5)

    rho_s, w99_s, ttft_s = _pool_eval(
        alpha_s, i1_s, i2_s, p99_s, nmax_s, w_s, h_s, chunk_s, n_s,
        input_frac, lam, empty_s, interpret)
    rho_l, w99_l, ttft_l = _pool_eval(
        alpha_l, i1_l, i2_l, p99_l, nmax_l, w_l, h_l, chunk_l, n_l,
        input_frac, lam, empty_l, interpret)

    cost = n_s * cost_s + n_l * cost_l

    ok_s = empty_s | ((rho_s <= RHO_MAX) & (ttft_s <= slo))
    ok_l = empty_l | ((rho_l <= RHO_MAX) & (ttft_l <= slo))
    # A candidate that routes traffic long but has no long pool is invalid.
    dangling = (alpha_l > 1e-9) & (n_l < 0.5)
    feasible = (ok_s & ok_l & ~dangling).astype(jnp.float32)

    return jnp.stack(
        [rho_s, rho_l, ttft_s, ttft_l, w99_s, w99_l, cost, feasible], axis=1)


def sweep_eval_flat(hist, cand, interpret: bool = True):
    """Flat-tensor entry point used for AOT lowering.

    hist: [2, K]  — row 0 = bin probabilities, row 1 = bin token budgets
    cand: [F, N]  — rows ordered per CANDIDATE_FIELDS
    returns [N, 8] per OUTPUT_COLUMNS.
    """
    fields = [cand[i] for i in range(len(CANDIDATE_FIELDS))]
    return sweep_eval(hist[0], hist[1], *fields, interpret=interpret)


def lower_sweep(n: int = N_CAND, k: int = K_BINS, interpret: bool = True):
    """jax.jit-lower sweep_eval_flat at the static artifact shape."""
    hist = jax.ShapeDtypeStruct((2, k), jnp.float32)
    cand = jax.ShapeDtypeStruct((len(CANDIDATE_FIELDS), n), jnp.float32)
    fn = lambda h, c: (sweep_eval_flat(h, c, interpret=interpret),)
    return jax.jit(fn).lower(hist, cand)
