"""Tests for the L2 sweep model (feasibility logic, Kimura wait, shapes)."""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import (CANDIDATE_FIELDS, OUTPUT_COLUMNS, RHO_MAX,
                           kimura_w99, sweep_eval_flat, N_CAND, K_BINS)

COL = {name: i for i, name in enumerate(OUTPUT_COLUMNS)}
FLD = {name: i for i, name in enumerate(CANDIDATE_FIELDS)}


def make_hist(k=K_BINS):
    """Simple chat-like histogram: geometric lengths, most mass short."""
    lens = np.geomspace(32, 65536, k).astype(np.float32)
    p = (1.0 / lens) ** 0.8
    p = (p / p.sum()).astype(np.float32)
    return np.stack([p, lens])


def make_cand(n=256, **over):
    cand = np.zeros((len(CANDIDATE_FIELDS), n), np.float32)
    base = dict(b_short=4096, n_s=4, n_l=4, chunk_s=512, chunk_l=512,
                nmax_s=128, nmax_l=16, w_s=8.0, h_s=0.65, w_l=8.0,
                h_l=0.65, cost_s=19400, cost_l=19400, input_frac=0.7,
                lam=0.02, slo=500.0)
    base.update(over)
    for name, val in base.items():
        cand[FLD[name]] = val
    return cand


def run(hist, cand):
    n = cand.shape[1]
    if n < N_CAND:
        cand = np.concatenate(
            [cand, np.zeros((cand.shape[0], N_CAND - n), np.float32)], axis=1)
        cand[FLD["n_s"], n:] = 1
        cand[FLD["nmax_s"], n:] = 1
        cand[FLD["nmax_l"], n:] = 1
        cand[FLD["w_s"], n:] = 1
        cand[FLD["h_s"], n:] = 0.1
        cand[FLD["w_l"], n:] = 1
        cand[FLD["h_l"], n:] = 0.1
        cand[FLD["chunk_s"], n:] = 512
        cand[FLD["chunk_l"], n:] = 512
        cand[FLD["b_short"], n:] = 1e9
    out = sweep_eval_flat(jnp.array(hist), jnp.array(cand))
    return np.asarray(out)[:n]


def test_output_shape_and_columns():
    out = run(make_hist(), make_cand(8))
    assert out.shape == (8, len(OUTPUT_COLUMNS))


def test_cost_arithmetic():
    out = run(make_hist(), make_cand(4, n_s=3, n_l=5, cost_s=8850,
                                     cost_l=35200))
    assert out[0, COL["cost_yr"]] == pytest.approx(3 * 8850 + 5 * 35200)


def test_overload_is_infeasible():
    # Absurd arrival rate: rho >> 1, ttft = inf, feasible = 0.
    out = run(make_hist(), make_cand(4, lam=10.0))
    assert out[0, COL["rho_s"]] > 1.0
    assert out[0, COL["feasible"]] == 0.0
    assert np.isinf(out[0, COL["ttft99_s"]])


def test_light_load_is_feasible():
    out = run(make_hist(), make_cand(4, lam=1e-4, n_s=8, n_l=8, slo=5000.0))
    assert out[0, COL["rho_s"]] < RHO_MAX
    assert out[0, COL["feasible"]] == 1.0


def test_homogeneous_candidate_ignores_long_pool():
    # b_short beyond max length: everything short, n_l = 0 is valid.
    out = run(make_hist(), make_cand(4, b_short=1e8, n_l=0, lam=1e-4,
                                     nmax_s=16, slo=10000.0))
    assert out[0, COL["rho_l"]] == 0.0
    assert out[0, COL["ttft99_l"]] == 0.0
    assert out[0, COL["feasible"]] == 1.0


def test_dangling_long_traffic_is_invalid():
    # Long traffic exists but n_l = 0 -> invalid candidate.
    out = run(make_hist(), make_cand(4, b_short=1024, n_l=0, lam=1e-4))
    assert out[0, COL["feasible"]] == 0.0


def test_utilization_cap_enforced():
    # The (0.85, 1) rho band is narrow in lam under the equilibrium-batch
    # model (rho rises steeply near token saturation), so refine in two
    # stages: coarse geomspace to bracket, fine linspace inside the
    # bracket.
    hist = make_hist()
    coarse = np.geomspace(1e-4, 1e-1, 60)
    cand = np.concatenate([make_cand(1, lam=l) for l in coarse], axis=1)
    rhos = run(hist, cand)[:, COL["rho_s"]]
    below = np.where(rhos <= RHO_MAX)[0].max()
    lo, hi = coarse[below], coarse[min(below + 1, len(coarse) - 1)]
    fine = np.linspace(lo, hi, 512)
    cand = np.concatenate([make_cand(1, lam=l) for l in fine], axis=1)
    out = run(hist, cand)
    rhos = out[:, COL["rho_s"]]
    inside = (rhos > RHO_MAX) & (rhos < 1.0)
    assert inside.any(), f"no lam hit the (0.85, 1) band: {rhos.min()}..{rhos.max()}"
    assert (out[inside, COL["feasible"]] == 0.0).all()


def test_more_gpus_reduce_wait():
    hist = make_hist()
    w = []
    for n_s in [2, 4, 8, 16]:
        out = run(hist, make_cand(4, n_s=n_s, lam=5e-3))
        w.append(out[0, COL["w99_s"]])
    assert all(a >= b for a, b in zip(w, w[1:]))


def test_kimura_mm1_consistency():
    # Exponential service (cs2 = 1, ratio E[S^2]/E[S]^2 = 2): Kimura
    # reduces to W99 = rho/(mu (1-rho)) ln(100) for c = 1.
    es, rho = 50.0, 0.6
    w = float(kimura_w99(jnp.float32(rho), jnp.float32(1.0),
                         jnp.float32(es), jnp.float32(2.0),
                         jnp.float32(rho)))
    want = rho / ((1 / es) * (1 - rho)) * math.log(100.0)
    assert w == pytest.approx(want, rel=1e-5)


def test_kimura_unstable_is_inf():
    w = float(kimura_w99(jnp.float32(1.0), jnp.float32(2.0),
                         jnp.float32(10.0), jnp.float32(3.0),
                         jnp.float32(1.0)))
    assert math.isinf(w)


def test_high_variance_increases_wait():
    es, rho, c = 50.0, 0.7, 4.0
    lo = float(kimura_w99(jnp.float32(0.3), jnp.float32(c), jnp.float32(es),
                          jnp.float32(1.5), jnp.float32(rho)))
    hi = float(kimura_w99(jnp.float32(0.3), jnp.float32(c), jnp.float32(es),
                          jnp.float32(50.0), jnp.float32(rho)))
    assert hi > lo * 5


def test_equilibrium_batch_properties():
    from compile.model import equilibrium_batch
    import numpy as np
    # Zero load floors at 1; saturation pins at n_eff; interior follows
    # n = aW/(1-aH).
    w, h, n_eff = 8.0, 0.65, 128.0
    assert float(equilibrium_batch(w, h, n_eff, jnp.float32(0.0))) == 1.0
    assert float(equilibrium_batch(w, h, n_eff, jnp.float32(10.0))) == n_eff
    a = 1.0
    want = a * w / (1 - a * h)
    got = float(equilibrium_batch(w, h, n_eff, jnp.float32(a)))
    assert got == pytest.approx(want, rel=1e-5)
