"""AOT artifact emission tests: HLO text round-trip prerequisites."""

import json
import os

import pytest

from compile.aot import build
from compile.model import CANDIDATE_FIELDS, OUTPUT_COLUMNS


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    out = tmp_path_factory.mktemp("aot") / "sweep.hlo.txt"
    meta = build(str(out), n=512, k=64)
    return str(out), meta


def test_writes_hlo_text(artifact):
    path, meta = artifact
    assert os.path.exists(path)
    text = open(path).read()
    assert text.startswith("HloModule")
    assert meta["hlo_bytes"] == len(text)


def test_entry_layout_matches_shapes(artifact):
    path, _ = artifact
    head = open(path).readline()
    assert "f32[2,64]" in head           # histogram input
    assert f"f32[{len(CANDIDATE_FIELDS)},512]" in head  # candidate input
    assert "f32[512,8]" in head          # output


def test_meta_sidecar(artifact):
    path, meta = artifact
    meta_path = path.replace(".hlo.txt", ".meta.json")
    assert os.path.exists(meta_path)
    loaded = json.load(open(meta_path))
    assert loaded["candidate_fields"] == list(CANDIDATE_FIELDS)
    assert loaded["output_columns"] == list(OUTPUT_COLUMNS)
    assert loaded["n_cand"] == 512
    assert loaded["k_bins"] == 64


def test_no_custom_calls(artifact):
    # interpret=True must fold the Pallas kernels into plain HLO: a Mosaic
    # custom-call would be unloadable by the CPU PJRT client.
    path, _ = artifact
    text = open(path).read()
    assert "custom-call" not in text
