"""Kernel-vs-oracle and invariant tests for the pool-moments Pallas kernel."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.moments import pool_moments, TILE
from compile.kernels.ref import ref_pool_moments

NAMES = ["alpha_s", "i1_s", "i2_s", "i1_l", "i2_l",
         "p99_len_s", "p99_len_l"]


def make_hist(rng, k):
    lens = np.sort(rng.uniform(16, 65536, k)).astype(np.float32)
    p = rng.uniform(0.05, 1.0, k).astype(np.float32)
    p /= p.sum()
    return p, lens


def run_both(p, lens, b, frac, cs, cl):
    n = len(b)
    pad = ((n + TILE - 1) // TILE) * TILE - n

    def padded(a, fill):
        return jnp.array(np.concatenate(
            [np.asarray(a, np.float32), np.full(pad, fill, np.float32)]))

    args = [padded(b, 1.0), padded(frac, 0.5), padded(cs, 512),
            padded(cl, 512)]
    out = pool_moments(jnp.array(p), jnp.array(lens), *args)
    got = {nm: np.asarray(o)[:n] for nm, o in zip(NAMES, out)}
    ref = ref_pool_moments(p, lens, jnp.array(b, jnp.float32),
                           jnp.array(frac, jnp.float32),
                           jnp.array(cs, jnp.float32),
                           jnp.array(cl, jnp.float32))
    want = {nm: np.asarray(ref[nm])[:n] for nm in NAMES}
    return got, want


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    k=st.sampled_from([16, 64, 128, 256]),
    frac=st.floats(0.05, 0.95),
)
def test_hypothesis_kernel_vs_oracle(seed, k, frac):
    rng = np.random.default_rng(seed)
    p, lens = make_hist(rng, k)
    n = 32
    b = rng.choice([256, 512, 1024, 4096, 8192, 32768, 70000], n)
    fr = np.full(n, frac, np.float32)
    cs = rng.choice([256, 512, 1024], n).astype(np.float32)
    cl = rng.choice([256, 512, 1024], n).astype(np.float32)
    got, want = run_both(p, lens, b, fr, cs, cl)
    for nm in NAMES:
        np.testing.assert_allclose(got[nm], want[nm], rtol=1e-5, atol=1e-6,
                                   err_msg=nm)


def _simple_case(b_vals, k=64, seed=3, frac=0.7):
    rng = np.random.default_rng(seed)
    p, lens = make_hist(rng, k)
    n = len(b_vals)
    ones = np.ones(n, np.float32)
    got, _ = run_both(p, lens, np.asarray(b_vals, np.float32),
                      np.full(n, frac, np.float32),
                      512 * ones, 1024 * ones)
    return p, lens, got


def test_alpha_monotone_in_threshold():
    bs = [256, 512, 1024, 4096, 8192, 32768, 70000]
    _, _, got = _simple_case(bs)
    assert np.all(np.diff(got["alpha_s"]) >= 0)
    assert got["alpha_s"][-1] == pytest.approx(1.0)


def test_second_moment_dominates_mean_square():
    _, _, got = _simple_case([512, 4096, 8192, 32768])
    for side in ["s", "l"]:
        i1 = got[f"i1_{side}"]
        i2 = got[f"i2_{side}"]
        mask = i1 > 0
        assert np.all(i2[mask] >= i1[mask] ** 2 * (1 - 1e-5))


def test_empty_long_pool_zeroed():
    _, _, got = _simple_case([70000])
    assert got["alpha_s"][0] == pytest.approx(1.0)
    assert got["i1_l"][0] == 0.0
    assert got["p99_len_l"][0] == 0.0


def test_p99_length_bounds():
    p, lens, got = _simple_case([4096])
    # Short-pool P99 must lie inside the short range; long above threshold.
    assert got["p99_len_s"][0] <= 4096
    assert got["p99_len_l"][0] > 4096
    assert got["p99_len_l"][0] <= lens.max()


def test_mean_iters_match_hand_computation():
    # Two-bin histogram with all mass short: E[S] is exactly computable.
    p = np.array([0.75, 0.25], np.float32)
    lens = np.array([1000.0, 2000.0], np.float32)
    one = np.ones(1, np.float32)
    got, _ = run_both(p, lens, np.array([4096.0], np.float32),
                      np.array([0.5], np.float32), 512 * one, 512 * one)
    # L=1000: L_in=500, L_out=500, iters = ceil(500/512)+500 = 501
    # L=2000: L_in=1000, L_out=1000, iters = 2+1000 = 1002
    want = 0.75 * 501 + 0.25 * 1002
    assert got["i1_s"][0] == pytest.approx(want, rel=1e-6)
    assert got["i2_s"][0] == pytest.approx(0.75 * 501**2 + 0.25 * 1002**2,
                                           rel=1e-6)
