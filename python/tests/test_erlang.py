"""Kernel-vs-oracle tests for the Erlang-C Pallas kernel (paper Eq. 1).

The kernel uses the Erlang-B recurrence; the oracle (ref.py) uses a
log-space closed form — agreement cross-checks two independent derivations.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.erlang import erlang_c, TILE
from compile.kernels.ref import ref_erlang_c, ref_erlang_b, C_MAX


def _pad(a, fill):
    n = ((len(a) + TILE - 1) // TILE) * TILE
    return np.concatenate([a, np.full(n - len(a), fill, np.float32)])


def kernel_erlang(rho, c):
    rho = np.asarray(rho, np.float32)
    c = np.asarray(c, np.float32)
    n = len(rho)
    out = erlang_c(jnp.array(_pad(rho, 0.5)), jnp.array(_pad(c, 1.0)))
    return np.asarray(out)[:n]


# ---------------------------------------------------------------- closed forms

def erlang_c_closed(rho, c):
    """Textbook Erlang-C via direct summation (float64, small c only)."""
    a = rho * c
    s = sum(a**k / math.factorial(k) for k in range(c))
    top = a**c / (math.factorial(c) * (1 - rho))
    return top / (s + top)


@pytest.mark.parametrize("rho", [0.1, 0.3, 0.5, 0.7, 0.85, 0.95, 0.99])
def test_mm1_equals_rho(rho):
    # For c=1, Erlang-C reduces to P(wait) = rho exactly.
    out = kernel_erlang([rho], [1.0])
    assert out[0] == pytest.approx(rho, rel=1e-5)


@pytest.mark.parametrize("c", [2, 3, 5, 10, 24, 40])
@pytest.mark.parametrize("rho", [0.2, 0.5, 0.8, 0.95])
def test_matches_textbook_closed_form(c, rho):
    out = kernel_erlang([rho], [float(c)])
    assert out[0] == pytest.approx(erlang_c_closed(rho, c), rel=1e-4, abs=1e-7)


def test_unstable_lanes_return_one():
    out = kernel_erlang([1.0, 1.5, 10.0], [4.0, 4.0, 4.0])
    assert np.all(out == 1.0)


def test_zero_load():
    out = kernel_erlang([0.0], [8.0])
    assert out[0] == pytest.approx(0.0, abs=1e-7)


def test_monotone_in_rho():
    rhos = np.linspace(0.05, 0.95, 19, dtype=np.float32)
    out = kernel_erlang(rhos, np.full(19, 16.0, np.float32))
    assert np.all(np.diff(out) > 0)


def test_monotone_decreasing_in_c():
    # At fixed rho, more servers -> lower waiting probability.
    cs = np.array([1, 2, 4, 8, 16, 32, 64, 128, 256, 512], np.float32)
    out = kernel_erlang(np.full(len(cs), 0.8, np.float32), cs)
    assert np.all(np.diff(out) < 0)


@settings(max_examples=200, deadline=None)
@given(
    rho=st.floats(0.0, 1.2),
    c=st.integers(1, C_MAX),
)
def test_hypothesis_kernel_vs_oracle(rho, c):
    got = kernel_erlang([rho], [float(c)])[0]
    want = float(ref_erlang_c(jnp.float32(rho), jnp.float32(c)))
    assert got == pytest.approx(want, rel=1e-3, abs=5e-5)


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 3),          # batches of TILE-multiples
    seed=st.integers(0, 2**31),
)
def test_hypothesis_batched_shapes(n, seed):
    rng = np.random.default_rng(seed)
    size = n * TILE
    rho = rng.uniform(0, 1.1, size).astype(np.float32)
    c = rng.integers(1, C_MAX + 1, size).astype(np.float32)
    got = np.asarray(erlang_c(jnp.array(rho), jnp.array(c)))
    want = np.asarray(ref_erlang_c(jnp.array(rho), jnp.array(c)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=5e-5)


def test_erlang_b_recurrence_identity():
    # Spot-check the oracle itself: B(1, a) = a / (1 + a).
    for a in [0.1, 0.5, 1.0, 3.0]:
        b = float(ref_erlang_b(jnp.float32(a), jnp.float32(1.0)))
        assert b == pytest.approx(a / (1 + a), rel=1e-5)
